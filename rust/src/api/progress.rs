//! Progress observation for long-running counting jobs. The coordinator
//! invokes these callbacks synchronously as events happen — run/iteration
//! events from its run loop, and per-exchange-step events from whichever
//! rank worker thread completed the step when the rank-parallel executor
//! is active — so CLIs can stream status lines and services can push job
//! state without polling. All methods have empty defaults — implement
//! only what you need.

/// Observer of a counting run. Implementations must be `Send + Sync`
/// because a session may be driven from a worker thread; callbacks take
/// `&self`, so use interior mutability (atomics, mutexes) for state.
pub trait Progress: Send + Sync {
    /// Called once before the first iteration. `n_subtemplates` is the
    /// size of the partition DAG (leaves included).
    fn on_run_start(&self, _n_iterations: usize, _n_subtemplates: usize) {}

    /// Called at the start of every color-coding iteration.
    fn on_iteration(&self, _iteration: usize, _n_iterations: usize) {}

    /// Called before a non-leaf subtemplate combine. `n_steps` is the
    /// exchange step count `W` (1 for all-to-all); `pipelined` says
    /// whether the Adaptive-Group ring was chosen.
    fn on_subtemplate_start(&self, _sub: usize, _n_steps: usize, _pipelined: bool) {}

    /// Called after each exchange step of subtemplate `sub` completes on
    /// every rank.
    fn on_exchange_step(&self, _sub: usize, _step: usize, _n_steps: usize) {}

    /// Called right after [`Progress::on_exchange_step`] when the
    /// rank-parallel pipelined executor ran the step: `comp_s` is the
    /// rank-averaged wall seconds spent folding the step's received rows,
    /// `wait_s` the rank-averaged seconds blocked waiting for them (the
    /// step's *exposed* communication; `comp_s / (comp_s + wait_s)` is
    /// the measured overlap ρ). Not called by the sequential executor,
    /// which has no real overlap to measure.
    fn on_exchange_measured(&self, _sub: usize, _step: usize, _comp_s: f64, _wait_s: f64) {}

    /// Called once a subtemplate's combine (local + exchange) is done.
    fn on_subtemplate_done(&self, _sub: usize) {}

    /// Called once after the last iteration.
    fn on_run_end(&self) {}
}

/// A ready-made observer that prints one status line per subtemplate to
/// stderr — what `harpsg count` attaches under `--progress`.
#[derive(Debug, Default)]
pub struct StderrProgress;

impl Progress for StderrProgress {
    fn on_iteration(&self, iteration: usize, n_iterations: usize) {
        eprintln!("[harpsg] iteration {}/{n_iterations}", iteration + 1);
    }

    fn on_subtemplate_start(&self, sub: usize, n_steps: usize, pipelined: bool) {
        eprintln!(
            "[harpsg]   subtemplate {sub}: {} exchange, {n_steps} step(s)",
            if pipelined { "ring" } else { "all-to-all" }
        );
    }
}
