//! Typed, validated counting jobs. `CountJob` is the only way work enters
//! a [`super::Session`]: the builder checks every cross-field consistency
//! rule up front so the coordinator never has to panic on a bad config.

use super::error::HarpsgError;
use crate::colorcount::{KernelMode, PruneMode, StorageMode};
use crate::comm::{AdaptivePolicy, HockneyParams};
use crate::coordinator::{
    validate_group_size, EngineKind, ExchangeExec, FabricKind, ModeSelect, RunConfig,
};
use crate::graph::GraphStorageMode;
use crate::template::{builtin, Template};

/// A validated request to count one template. Construct with
/// [`CountJob::builder`]; run with [`super::Session::count`].
///
/// ```no_run
/// use harpsg::api::{CountJob, Session};
/// use harpsg::graph::Dataset;
/// use harpsg::template::builtin;
///
/// let session = Session::new(Dataset::R500K3.generate(2000));
/// let job = CountJob::builder(builtin("u5-2").unwrap())
///     .ranks(8)
///     .iterations(4)
///     .build()
///     .unwrap();
/// let report = session.count(&job).unwrap();
/// println!("{}", report.to_json_string());
/// ```
#[derive(Debug, Clone)]
pub struct CountJob {
    pub template: Template,
    pub(crate) cfg: RunConfig,
    pub(crate) group_size: Option<usize>,
}

impl CountJob {
    /// Start a builder for `template` with the crate defaults
    /// (`RunConfig::default()`).
    pub fn builder(template: Template) -> CountJobBuilder {
        CountJobBuilder {
            template,
            cfg: RunConfig::default(),
            group_size: None,
            task_size_set: false,
        }
    }

    /// Convenience: builder for a builtin template by its paper name.
    pub fn of_builtin(name: &str) -> Result<CountJobBuilder, HarpsgError> {
        let t = builtin(name).map_err(|e| HarpsgError::Template(format!("{e:#}")))?;
        Ok(Self::builder(t))
    }

    /// The validated run configuration.
    pub fn config(&self) -> &RunConfig {
        &self.cfg
    }
}

/// Builder for [`CountJob`]; every setter is chainable and `build()`
/// performs the validation.
#[derive(Debug, Clone)]
pub struct CountJobBuilder {
    template: Template,
    cfg: RunConfig,
    group_size: Option<usize>,
    task_size_set: bool,
}

impl CountJobBuilder {
    /// Number of simulated ranks (≥ 1).
    pub fn ranks(mut self, n: usize) -> Self {
        self.cfg.n_ranks = n;
        self
    }

    /// Virtual threads per rank for the replay model (≥ 1).
    pub fn threads(mut self, n: usize) -> Self {
        self.cfg.n_threads = n;
        self
    }

    /// Real combine-executor threads (1..=512, the CLI's `--workers`).
    /// Unlike [`Self::threads`] — the *modeled* virtual-thread count —
    /// this spawns actual OS threads for every combine. Counts and
    /// estimates are bit-identical for any value; only the measured
    /// per-worker record in the report changes.
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.n_workers = n;
        self
    }

    /// Color-coding iterations (≥ 1).
    pub fn iterations(mut self, n: usize) -> Self {
        self.cfg.n_iterations = n;
        self
    }

    /// Coloring seed (the partition seed belongs to the session).
    pub fn seed(mut self, s: u64) -> Self {
        self.cfg.seed = s;
        self
    }

    /// Table-1 mode (Naive / Pipeline / Adaptive / AdaptiveLB).
    pub fn mode(mut self, m: ModeSelect) -> Self {
        self.cfg.mode = m;
        self
    }

    /// Combine backend; `EngineKind::Xla` additionally requires the
    /// session to have been opened with `load_xla`.
    pub fn engine(mut self, e: EngineKind) -> Self {
        self.cfg.engine = e;
        self
    }

    /// Exchange executor: the rank-parallel pipelined executor (default)
    /// or the sequential reference path. Estimates are bit-identical
    /// either way; only the measured-pipeline report and the real
    /// wall-clock change.
    pub fn exchange(mut self, e: ExchangeExec) -> Self {
        self.cfg.exchange = e;
        self
    }

    /// Count-table storage (the CLI's `--table-storage`): `Dense` (the
    /// historical layout, default), `Sparse`, or `Auto` — pick per table
    /// from the measured density, storing and shipping sparse where it
    /// pays. Estimates are bit-identical for every choice; the report's
    /// `storage` section and memory peaks show what changed.
    pub fn table_storage(mut self, s: StorageMode) -> Self {
        self.cfg.table_storage = s;
        self
    }

    /// Combine kernel (the CLI's `--kernel`): `Scalar` (the historical
    /// loops, default — and the differential baseline), `Simd` (the
    /// chunked-lane SpMM + fused eMA row-block executor), or `Auto`
    /// (pick per combine from the aggregation width). Bit-identical on
    /// integer-valued DP tables; see `colorcount::kernel` for the
    /// tolerance policy on fractional data. Results never depend on the
    /// worker count either way.
    pub fn kernel(mut self, k: KernelMode) -> Self {
        self.cfg.kernel = k;
        self
    }

    /// Frontier pruning (the CLI's `--prune`): `Off` (the historical
    /// full-table combine, default — and the differential baseline),
    /// `On` (every combine consults the child tables' nonzero-row
    /// frontiers to skip dead aggregation pairs, contraction rows, and
    /// wire rows), or `Auto` (prune per table only when the measured
    /// frontier occupancy is low enough to pay). Counts and estimates
    /// are bit-identical for every choice — pruning only elides exact
    /// zeros; the report's `prune` section shows what was skipped.
    pub fn prune(mut self, p: PruneMode) -> Self {
        self.cfg.prune = p;
        self
    }

    /// Graph storage backend (the CLI's `--graph-storage`): `Resident`
    /// (the historical shared CSR, default), `Mmap` (per-rank segment
    /// files — each rank owns only its vertex partition's adjacency
    /// slice), or `Auto` (mmap exactly when the full CSR exceeds the
    /// resident-adjacency budget). Estimates are bit-identical for every
    /// choice; the report's `config.graph_storage` and
    /// `memory.graph_resident_per_rank` show what changed.
    pub fn graph_storage(mut self, s: GraphStorageMode) -> Self {
        self.cfg.graph_storage = s;
        self
    }

    /// Resident-adjacency budget in bytes for `GraphStorageMode::Auto`
    /// (the CLI's `--graph-budget-mb`). Ignored by the explicit modes;
    /// unset, `Auto` resolves against
    /// [`GraphStorageMode::DEFAULT_BUDGET`].
    pub fn graph_budget(mut self, bytes: u64) -> Self {
        self.cfg.graph_budget = Some(bytes);
        self
    }

    /// Rank transport (the CLI's `--fabric`): `Threaded` (simulated
    /// ranks inside one process, default) or `Socket` (one OS process
    /// per rank over TCP/Unix sockets). Socket jobs run through the
    /// `coordinator::procmode` launcher — `Session::count` rejects them
    /// with a typed error pointing there — and require the native
    /// engine (validated in `build`). Estimates are bit-identical
    /// either way; the report's `link` section carries the measured
    /// per-rank α/β in socket mode.
    pub fn fabric(mut self, f: FabricKind) -> Self {
        self.cfg.fabric = f;
        self
    }

    /// Alg-4 neighbor-list task size — only meaningful for
    /// `ModeSelect::AdaptiveLb` (validated in `build`).
    pub fn task_size(mut self, s: u32) -> Self {
        self.cfg.task_size = s;
        self.task_size_set = true;
        self
    }

    /// Per-rank modeled memory budget in bytes.
    pub fn mem_limit(mut self, bytes: u64) -> Self {
        self.cfg.mem_limit = Some(bytes);
        self
    }

    /// Hockney network parameters for the model clock.
    pub fn net(mut self, net: HockneyParams) -> Self {
        self.cfg.net = net;
        self
    }

    /// Adaptive-switch tunables (intensity threshold, flop time).
    pub fn policy(mut self, policy: AdaptivePolicy) -> Self {
        self.cfg.policy = policy;
        self
    }

    /// Model-driven per-subtemplate group-size selection (the coordinator
    /// sweep + runtime calibration feedback). Only meaningful for the
    /// Adaptive/AdaptiveLB modes (validated in `build`); the static
    /// intensity switch with g = 1 remains the default.
    pub fn adaptive(mut self, on: bool) -> Self {
        self.cfg.adaptive_group = on;
        self
    }

    /// Ablation hook: force the ring group size. Feasibility (2g+1 ≤
    /// ranks, or g = ranks-1 for all-to-all) is validated in `build`.
    pub fn group_size(mut self, g: usize) -> Self {
        self.group_size = Some(g);
        self
    }

    /// Replace the whole `RunConfig` wholesale — the escape hatch for the
    /// CLI's `run --config` path, which already parsed a full config.
    /// Field-level setters applied *after* this still work; validation in
    /// `build()` applies either way.
    pub fn config(mut self, cfg: RunConfig) -> Self {
        self.cfg = cfg;
        self.task_size_set = false;
        self
    }

    /// Validate and seal the job.
    pub fn build(self) -> Result<CountJob, HarpsgError> {
        let cfg = &self.cfg;
        if cfg.n_ranks == 0 {
            return Err(HarpsgError::InvalidJob("n_ranks must be ≥ 1".into()));
        }
        if cfg.n_ranks > u16::MAX as usize {
            return Err(HarpsgError::InvalidJob(format!(
                "n_ranks {} exceeds the partition limit of {}",
                cfg.n_ranks,
                u16::MAX
            )));
        }
        if cfg.n_threads == 0 {
            return Err(HarpsgError::InvalidJob("n_threads must be ≥ 1".into()));
        }
        if cfg.n_workers == 0 {
            return Err(HarpsgError::InvalidJob(
                "n_workers must be ≥ 1 (real combine-executor threads)".into(),
            ));
        }
        if cfg.n_workers > 512 {
            return Err(HarpsgError::InvalidJob(format!(
                "n_workers {} exceeds the executor limit of 512",
                cfg.n_workers
            )));
        }
        if cfg.n_iterations == 0 {
            return Err(HarpsgError::InvalidJob("n_iterations must be ≥ 1".into()));
        }
        if cfg.phys_cores == 0 {
            return Err(HarpsgError::InvalidJob("phys_cores must be ≥ 1".into()));
        }
        if cfg.mode == ModeSelect::AdaptiveLb && cfg.task_size == 0 {
            return Err(HarpsgError::InvalidJob(
                "adaptive-lb needs task_size ≥ 1 (neighbor-list partitioning granularity)".into(),
            ));
        }
        if self.task_size_set && cfg.mode != ModeSelect::AdaptiveLb {
            return Err(HarpsgError::InvalidJob(format!(
                "task_size only applies to adaptive-lb; mode is {}",
                cfg.mode.flag()
            )));
        }
        if cfg.adaptive_group
            && !matches!(cfg.mode, ModeSelect::Adaptive | ModeSelect::AdaptiveLb)
        {
            return Err(HarpsgError::InvalidJob(format!(
                "adaptive group selection only applies to adaptive/adaptive-lb; mode is {}",
                cfg.mode.flag()
            )));
        }
        if cfg.fabric == FabricKind::Socket && cfg.engine == EngineKind::Xla {
            return Err(HarpsgError::InvalidJob(
                "the socket fabric requires the native engine (rank processes \
                 cannot share an XLA runtime)"
                    .into(),
            ));
        }
        if let Some(g) = self.group_size {
            if cfg.adaptive_group {
                return Err(HarpsgError::InvalidJob(
                    "group_size (the forced-ring ablation) and adaptive group \
                     selection are mutually exclusive"
                        .into(),
                ));
            }
            validate_group_size(g, cfg.n_ranks)?;
        }
        Ok(CountJob {
            template: self.template,
            cfg: self.cfg,
            group_size: self.group_size,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> CountJobBuilder {
        CountJob::of_builtin("u5-2").unwrap()
    }

    #[test]
    fn defaults_build() {
        let job = base().build().unwrap();
        assert_eq!(job.config().n_ranks, RunConfig::default().n_ranks);
        assert_eq!(job.template.name, "u5-2");
    }

    #[test]
    fn rejects_zero_ranks_threads_iterations() {
        assert!(matches!(
            base().ranks(0).build(),
            Err(HarpsgError::InvalidJob(_))
        ));
        assert!(matches!(
            base().threads(0).build(),
            Err(HarpsgError::InvalidJob(_))
        ));
        assert!(matches!(
            base().iterations(0).build(),
            Err(HarpsgError::InvalidJob(_))
        ));
    }

    #[test]
    fn worker_count_bounds() {
        assert!(matches!(
            base().workers(0).build(),
            Err(HarpsgError::InvalidJob(_))
        ));
        assert!(matches!(
            base().workers(513).build(),
            Err(HarpsgError::InvalidJob(_))
        ));
        assert_eq!(base().workers(8).build().unwrap().config().n_workers, 8);
    }

    #[test]
    fn rejects_oversized_rank_count() {
        let err = base().ranks(70_000).build().unwrap_err();
        assert!(matches!(err, HarpsgError::InvalidJob(_)));
        assert!(err.to_string().contains("partition limit"));
    }

    #[test]
    fn task_size_mode_consistency() {
        // adaptive-lb without granularity is inconsistent
        assert!(base().mode(ModeSelect::AdaptiveLb).task_size(0).build().is_err());
        // explicitly setting task size for a per-vertex mode is inconsistent
        assert!(base().mode(ModeSelect::Naive).task_size(50).build().is_err());
        // the valid combination passes
        assert!(base()
            .mode(ModeSelect::AdaptiveLb)
            .task_size(40)
            .build()
            .is_ok());
        // untouched defaults pass regardless of mode
        assert!(base().mode(ModeSelect::Naive).build().is_ok());
    }

    #[test]
    fn table_storage_knob() {
        assert_eq!(
            base().build().unwrap().config().table_storage,
            StorageMode::Dense,
            "dense layout stays the default"
        );
        for mode in [StorageMode::Dense, StorageMode::Sparse, StorageMode::Auto] {
            let job = base().table_storage(mode).build().unwrap();
            assert_eq!(job.config().table_storage, mode);
        }
        // orthogonal to every other knob, including the adaptive sweep
        assert!(base()
            .table_storage(StorageMode::Auto)
            .adaptive(true)
            .build()
            .is_ok());
    }

    #[test]
    fn kernel_knob() {
        assert_eq!(
            base().build().unwrap().config().kernel,
            KernelMode::Scalar,
            "scalar baseline stays the default"
        );
        for mode in [KernelMode::Scalar, KernelMode::Simd, KernelMode::Auto] {
            let job = base().kernel(mode).build().unwrap();
            assert_eq!(job.config().kernel, mode);
        }
        // orthogonal to storage and the adaptive sweep
        assert!(base()
            .kernel(KernelMode::Simd)
            .table_storage(StorageMode::Auto)
            .adaptive(true)
            .build()
            .is_ok());
    }

    #[test]
    fn prune_knob() {
        assert_eq!(
            base().build().unwrap().config().prune,
            PruneMode::Off,
            "the unpruned combine stays the default"
        );
        for mode in [PruneMode::On, PruneMode::Off, PruneMode::Auto] {
            let job = base().prune(mode).build().unwrap();
            assert_eq!(job.config().prune, mode);
        }
        // orthogonal to kernel, storage and the adaptive sweep
        assert!(base()
            .prune(PruneMode::Auto)
            .kernel(KernelMode::Simd)
            .table_storage(StorageMode::Auto)
            .adaptive(true)
            .build()
            .is_ok());
    }

    #[test]
    fn graph_storage_knob() {
        use crate::graph::GraphStorageMode as GS;
        let job = base().build().unwrap();
        assert_eq!(
            job.config().graph_storage,
            GS::Resident,
            "the resident CSR stays the default"
        );
        assert_eq!(job.config().graph_budget, None);
        for mode in [GS::Resident, GS::Mmap, GS::Auto] {
            let job = base().graph_storage(mode).build().unwrap();
            assert_eq!(job.config().graph_storage, mode);
        }
        let job = base()
            .graph_storage(GS::Auto)
            .graph_budget(64 << 20)
            .build()
            .unwrap();
        assert_eq!(job.config().graph_budget, Some(64 << 20));
        // orthogonal to the other knobs
        assert!(base()
            .graph_storage(GS::Mmap)
            .table_storage(StorageMode::Auto)
            .kernel(KernelMode::Auto)
            .adaptive(true)
            .build()
            .is_ok());
    }

    #[test]
    fn exchange_executor_knob() {
        assert_eq!(
            base().build().unwrap().config().exchange,
            ExchangeExec::Threaded,
            "rank-parallel pipelined executor is the default"
        );
        let job = base().exchange(ExchangeExec::Sequential).build().unwrap();
        assert_eq!(job.config().exchange, ExchangeExec::Sequential);
    }

    #[test]
    fn group_size_bounds() {
        // feasible rings (2g+1 ≤ P) and the g = P-1 all-to-all degenerate
        assert!(base().ranks(8).group_size(3).build().is_ok());
        assert!(base().ranks(8).group_size(7).build().is_ok());
        // the half-open band (P-1)/2 < g < P-1 is a typed error now
        for bad in [4usize, 5, 6] {
            assert!(
                base().ranks(8).group_size(bad).build().is_err(),
                "g={bad} must be infeasible at P=8"
            );
        }
        assert!(base().ranks(8).group_size(8).build().is_err());
        assert!(base().ranks(8).group_size(0).build().is_err());
        assert!(base().ranks(1).group_size(1).build().is_err());
        // P = 2 / P = 3 regression: only all-to-all (and g = 1 at P = 3)
        assert!(base().ranks(2).group_size(1).build().is_ok());
        assert!(base().ranks(2).group_size(2).build().is_err());
        assert!(base().ranks(3).group_size(1).build().is_ok());
        assert!(base().ranks(3).group_size(2).build().is_ok());
        assert!(base().ranks(3).group_size(3).build().is_err());
    }

    #[test]
    fn adaptive_knob_mode_consistency() {
        // default mode is adaptive-lb: the sweep is legal
        let job = base().adaptive(true).build().unwrap();
        assert!(job.config().adaptive_group);
        assert!(base()
            .mode(ModeSelect::Adaptive)
            .adaptive(true)
            .build()
            .is_ok());
        // fixed-shape modes cannot take the sweep
        for mode in [ModeSelect::Naive, ModeSelect::Pipeline] {
            let err = base().mode(mode).adaptive(true).build().unwrap_err();
            assert!(matches!(err, HarpsgError::InvalidJob(_)), "{mode:?}");
        }
        // the forced-ring ablation contradicts the sweep
        assert!(base()
            .ranks(8)
            .adaptive(true)
            .group_size(2)
            .build()
            .is_err());
        // off by default
        assert!(!base().build().unwrap().config().adaptive_group);
    }

    #[test]
    fn fabric_knob() {
        assert_eq!(
            base().build().unwrap().config().fabric,
            FabricKind::Threaded,
            "the in-process fabric stays the default"
        );
        let job = base().fabric(FabricKind::Socket).build().unwrap();
        assert_eq!(job.config().fabric, FabricKind::Socket);
        // rank processes cannot share an XLA runtime
        let err = base()
            .fabric(FabricKind::Socket)
            .engine(EngineKind::Xla)
            .build()
            .unwrap_err();
        assert!(matches!(err, HarpsgError::InvalidJob(_)));
        assert!(err.to_string().contains("native engine"), "{err}");
    }

    #[test]
    fn unknown_builtin_is_typed() {
        assert!(matches!(
            CountJob::of_builtin("u99-9"),
            Err(HarpsgError::Template(_))
        ));
    }

    #[test]
    fn config_override_still_validated() {
        let mut cfg = RunConfig::default();
        cfg.n_ranks = 0;
        assert!(base().config(cfg).build().is_err());
    }
}
