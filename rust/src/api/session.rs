//! The session: one loaded graph plus its amortized per-rank-count
//! exchange plans (partition, request lists, neighbor-pair plans) and an
//! optionally loaded XLA runtime, reused across every job it runs. This
//! is the unit a long-lived counting service holds per graph.

use super::error::HarpsgError;
use super::job::CountJob;
use super::progress::Progress;
use super::report::JobReport;
use crate::coordinator::{DistributedRunner, EngineKind, ExchangePlan, FabricKind, RunConfig};
use crate::graph::shard::shard_to_scratch;
use crate::graph::{Graph, Partition};
use crate::runtime::{XlaCombine, XlaRuntime};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// How the session partitions vertices across ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionKind {
    /// hashed random partition (the paper's Eq-5 assumption; default)
    Random,
    /// contiguous blocks (ablation A2)
    Block,
}

/// Session-level knobs. Jobs carry everything per-run (mode, iterations,
/// coloring seed, …); the session owns what is shared across jobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SessionOptions {
    /// seed for the random partition (mixed exactly like the historical
    /// per-runner path, so facade runs reproduce direct-runner runs)
    pub seed: u64,
    pub partition: PartitionKind,
    /// load the AOT XLA artifacts at session creation; required before
    /// any job may select `EngineKind::Xla`
    pub load_xla: bool,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            seed: 42,
            partition: PartitionKind::Random,
            load_xla: false,
        }
    }
}

/// A loaded graph plus its reusable distributed-run setup.
///
/// Building the exchange plan (partition + request lists + per-rank
/// neighbor-pair plans) walks every edge of the graph and dominates the
/// fixed cost of a run; the session builds it once per rank count and
/// shares it across templates, which is what makes multi-template sweeps
/// (GFD batches, the figure harness) cheap.
pub struct Session {
    graph: Graph,
    opts: SessionOptions,
    /// keyed by (rank count, sharded?): resident and mmap-built plans are
    /// bit-identical in structure but charge different ledger bytes, so
    /// they cache side by side
    plans: Mutex<HashMap<(usize, bool), Arc<ExchangePlan>>>,
    xla: Option<Arc<XlaRuntime>>,
}

impl Session {
    /// Open a session with default options (random partition, seed 42,
    /// no XLA). Never fails.
    pub fn new(graph: Graph) -> Session {
        Self::with_options(graph, SessionOptions::default())
            .expect("default session options cannot fail")
    }

    /// Open a session with explicit options. Fails only when `load_xla`
    /// is set and the PJRT artifacts cannot be loaded.
    pub fn with_options(graph: Graph, opts: SessionOptions) -> Result<Session, HarpsgError> {
        let xla = if opts.load_xla {
            let rt = XlaRuntime::load_default()
                .map_err(|e| HarpsgError::EngineUnavailable(format!("{e:#}")))?;
            Some(Arc::new(rt))
        } else {
            None
        };
        Ok(Session {
            graph,
            opts,
            plans: Mutex::new(HashMap::new()),
            xla,
        })
    }

    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    pub fn options(&self) -> &SessionOptions {
        &self.opts
    }

    /// Whether the XLA runtime is attached (jobs may select
    /// `EngineKind::Xla`).
    pub fn xla_loaded(&self) -> bool {
        self.xla.is_some()
    }

    /// The (resident) exchange plan for `n_ranks`, built on first use and
    /// cached. Exposed so tests and tools can observe the reuse
    /// (`Arc::ptr_eq`).
    pub fn plan(&self, n_ranks: usize) -> Arc<ExchangePlan> {
        self.plan_with_reuse(n_ranks, None)
            .expect("resident plan build cannot fail")
            .0
    }

    /// The partition this session cuts for `n_ranks` — identical for the
    /// resident and sharded backends by construction.
    fn partition_for(&self, n_ranks: usize) -> Partition {
        match self.opts.partition {
            PartitionKind::Random => {
                ExchangePlan::random_partition(&self.graph, n_ranks, self.opts.seed)
            }
            PartitionKind::Block => Partition::block(self.graph.n_vertices(), n_ranks),
        }
    }

    /// Fetch-or-build under one lock acquisition so concurrent counts
    /// agree on who built the plan (the bool is `true` when it came from
    /// the cache). When `cfg` resolves to sharded graph storage, the plan
    /// is built from scratch per-rank segment files — written, read back
    /// one slice at a time, and removed before this returns — and cached
    /// under its own key; the serialization through the cache lock also
    /// keeps concurrent shard builds from colliding on disk.
    fn plan_with_reuse(
        &self,
        n_ranks: usize,
        cfg: Option<&RunConfig>,
    ) -> Result<(Arc<ExchangePlan>, bool), HarpsgError> {
        let mmap = cfg.is_some_and(|c| {
            c.graph_storage
                .resolves_to_mmap(self.graph.bytes(), c.graph_budget)
        });
        let mut cache = self.plans.lock().unwrap();
        if let Some(plan) = cache.get(&(n_ranks, mmap)) {
            return Ok((plan.clone(), true));
        }
        let part = self.partition_for(n_ranks);
        let shard_err = |e: crate::graph::GraphLoadError| {
            HarpsgError::Io(format!("graph shard storage: {e}"))
        };
        let plan = if mmap {
            let seg = shard_to_scratch(&self.graph, &part).map_err(shard_err)?;
            ExchangePlan::from_segments(&seg, part).map_err(shard_err)?
        } else {
            ExchangePlan::build(&self.graph, part)
        };
        let plan = Arc::new(plan);
        cache.insert((n_ranks, mmap), plan.clone());
        Ok((plan, false))
    }

    /// How many rank counts have a cached plan.
    pub fn cached_plans(&self) -> usize {
        self.plans.lock().unwrap().len()
    }

    /// Run one job to completion and return its report.
    pub fn count(&self, job: &CountJob) -> Result<JobReport, HarpsgError> {
        self.count_observed(job, None)
    }

    /// Run one job with a progress observer attached; the observer's
    /// callbacks fire synchronously from the run loop.
    pub fn count_with_progress(
        &self,
        job: &CountJob,
        progress: Arc<dyn Progress>,
    ) -> Result<JobReport, HarpsgError> {
        self.count_observed(job, Some(progress))
    }

    /// Run several jobs against the shared setup. Reports come back in
    /// input order; all jobs after the first on a given rank count show
    /// `setup_reused = true`.
    pub fn count_batch(&self, jobs: &[CountJob]) -> Result<Vec<JobReport>, HarpsgError> {
        jobs.iter().map(|j| self.count(j)).collect()
    }

    fn count_observed(
        &self,
        job: &CountJob,
        progress: Option<Arc<dyn Progress>>,
    ) -> Result<JobReport, HarpsgError> {
        if job.cfg.fabric == FabricKind::Socket {
            // a session owns exactly one process; rank processes are the
            // launcher's job (`harpsg count --fabric socket` routes there)
            return Err(HarpsgError::InvalidJob(
                "socket-fabric jobs run through the rank-process launcher \
                 (coordinator::procmode::launch / `harpsg count --fabric socket`), \
                 not Session::count"
                    .into(),
            ));
        }
        if job.cfg.engine == EngineKind::Xla && self.xla.is_none() {
            return Err(HarpsgError::EngineUnavailable(
                "job selects the XLA engine but the session was opened without `load_xla`".into(),
            ));
        }
        let t0 = Instant::now();
        let (plan, reused) = self.plan_with_reuse(job.cfg.n_ranks, Some(&job.cfg))?;
        let setup_seconds = t0.elapsed().as_secs_f64();

        let mut runner = DistributedRunner::with_plan(
            &job.template,
            &self.graph,
            job.cfg.clone(),
            plan,
        );
        if let Some(g) = job.group_size {
            // already validated against the rank count in CountJob::build;
            // the runner re-checks and the typed error propagates
            runner.set_group_size(g)?;
        }
        if job.cfg.engine == EngineKind::Xla {
            if let Some(rt) = &self.xla {
                runner.xla = Some(XlaCombine::new(rt.clone()));
            }
        }
        if let Some(p) = progress {
            runner.set_progress(p);
        }
        let result = runner.run();
        Ok(JobReport::from_run(
            job,
            &self.graph,
            result,
            reused,
            setup_seconds,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::CountJob;
    use crate::graph::rmat::{generate, RmatParams};

    fn graph() -> Graph {
        generate(&RmatParams::with_skew(96, 500, 3, 5))
    }

    #[test]
    fn plans_are_cached_per_rank_count() {
        let s = Session::new(graph());
        let a = s.plan(4);
        let b = s.plan(4);
        assert!(Arc::ptr_eq(&a, &b));
        let c = s.plan(6);
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(s.cached_plans(), 2);
    }

    #[test]
    fn xla_job_without_runtime_is_rejected() {
        let s = Session::new(graph());
        let job = CountJob::of_builtin("u3-1")
            .unwrap()
            .ranks(3)
            .engine(EngineKind::Xla)
            .build()
            .unwrap();
        assert!(matches!(
            s.count(&job),
            Err(HarpsgError::EngineUnavailable(_))
        ));
    }

    #[test]
    fn socket_jobs_are_routed_to_the_launcher() {
        let s = Session::new(graph());
        let job = CountJob::of_builtin("u3-1")
            .unwrap()
            .ranks(3)
            .fabric(FabricKind::Socket)
            .build()
            .unwrap();
        let err = s.count(&job).unwrap_err();
        assert!(matches!(err, HarpsgError::InvalidJob(_)));
        assert!(err.to_string().contains("launcher"), "{err}");
    }

    #[test]
    fn setup_reuse_is_reported() {
        let s = Session::new(graph());
        let job = CountJob::of_builtin("u3-1").unwrap().ranks(4).build().unwrap();
        let first = s.count(&job).unwrap();
        let second = s.count(&job).unwrap();
        assert!(!first.setup_reused);
        assert!(second.setup_reused);
        assert_eq!(first.colorful, second.colorful);
    }

    #[test]
    fn workers_knob_is_bit_stable_via_facade() {
        // the facade-level acceptance check: --workers N reproduces
        // --workers 1 exactly, while the measured record reflects N
        let s = Session::new(graph());
        let mk = |w: usize| {
            CountJob::of_builtin("u5-2")
                .unwrap()
                .ranks(4)
                .iterations(2)
                .workers(w)
                .build()
                .unwrap()
        };
        let one = s.count(&mk(1)).unwrap();
        let four = s.count(&mk(4)).unwrap();
        assert_eq!(one.estimate.to_bits(), four.estimate.to_bits());
        assert_eq!(one.colorful, four.colorful);
        assert_eq!(one.n_workers, 1);
        assert_eq!(four.n_workers, 4);
        assert_eq!(four.workers.n_workers(), 4);
        assert_eq!(one.workers.n_pairs, four.workers.n_pairs);
        assert!(four.workers.n_pairs > 0);
    }

    #[test]
    fn block_partition_sessions_differ_from_random() {
        let g = graph();
        let s_rand = Session::new(g.clone());
        let s_block = Session::with_options(
            g,
            SessionOptions {
                partition: PartitionKind::Block,
                ..SessionOptions::default()
            },
        )
        .unwrap();
        // counting semantics are partition-invariant (up to float
        // summation order)…
        let job = CountJob::of_builtin("u5-2").unwrap().ranks(4).build().unwrap();
        let a = s_rand.count(&job).unwrap();
        let b = s_block.count(&job).unwrap();
        for (x, y) in a.colorful.iter().zip(&b.colorful) {
            let rel = (x - y).abs() / y.abs().max(1.0);
            assert!(rel < 1e-3, "colorful {x} vs {y}");
        }
        // …but the layouts genuinely differ
        assert_ne!(
            s_rand.plan(4).part.locals[0],
            s_block.plan(4).part.locals[0]
        );
    }
}
