//! The typed error surface of the facade. Library paths that used to
//! return stringly `anyhow` errors now classify failures so callers (the
//! CLI, future services) can branch on them; `HarpsgError` still converts
//! into `anyhow::Error` at the binary boundary because it implements
//! `std::error::Error`.

use std::fmt;

/// Every way the `harpsg::api` surface can fail.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum HarpsgError {
    /// a `CountJob` builder field failed validation
    InvalidJob(String),
    /// a config file or CLI value could not be parsed
    Parse(String),
    /// an unknown communication mode name
    UnknownMode(String),
    /// an unknown combine engine name
    UnknownEngine(String),
    /// an unknown config key or CLI flag
    UnknownFlag(String),
    /// the same CLI flag was passed twice
    DuplicateFlag(String),
    /// a flag without its value, or a required flag/key absent
    MissingValue(String),
    /// template name not in the builtin library and not a readable file
    Template(String),
    /// the requested engine cannot run (e.g. XLA without artifacts)
    EngineUnavailable(String),
    /// an I/O failure, annotated with the path involved
    Io(String),
    /// a rank transport failure (peer disconnect, timeout, bad frame),
    /// carrying the full `comm::FabricError` context as text
    Transport(String),
}

impl fmt::Display for HarpsgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HarpsgError::InvalidJob(m) => write!(f, "invalid job: {m}"),
            HarpsgError::Parse(m) => write!(f, "parse error: {m}"),
            HarpsgError::UnknownMode(m) => {
                write!(f, "unknown mode `{m}` (naive|pipeline|adaptive|adaptive-lb)")
            }
            HarpsgError::UnknownEngine(m) => write!(f, "unknown engine `{m}` (native|xla)"),
            HarpsgError::UnknownFlag(m) => write!(f, "unknown flag or key `{m}`"),
            HarpsgError::DuplicateFlag(m) => write!(f, "flag `{m}` given more than once"),
            HarpsgError::MissingValue(m) => write!(f, "missing value: {m}"),
            HarpsgError::Template(m) => write!(f, "template error: {m}"),
            HarpsgError::EngineUnavailable(m) => write!(f, "engine unavailable: {m}"),
            HarpsgError::Io(m) => write!(f, "io error: {m}"),
            HarpsgError::Transport(m) => write!(f, "transport error: {m}"),
        }
    }
}

impl std::error::Error for HarpsgError {}

impl From<crate::comm::FabricError> for HarpsgError {
    fn from(e: crate::comm::FabricError) -> Self {
        HarpsgError::Transport(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_actionable() {
        let e = HarpsgError::UnknownMode("warp".into());
        assert!(e.to_string().contains("warp"));
        assert!(e.to_string().contains("adaptive-lb"));
        let e = HarpsgError::DuplicateFlag("--ranks".into());
        assert!(e.to_string().contains("--ranks"));
    }

    #[test]
    fn transport_errors_keep_fabric_context() {
        let fe = crate::comm::FabricError::timeout(3, 2, "1 of 4 packet(s)").with_peer(1);
        let e: HarpsgError = fe.into();
        let s = e.to_string();
        assert!(s.starts_with("transport error:"), "{s}");
        assert!(s.contains("rank 3"), "{s}");
        assert!(s.contains("step 2"), "{s}");
        assert!(s.contains("peer 1"), "{s}");
        assert!(s.contains("TimedOut"), "{s}");
    }

    #[test]
    fn converts_into_anyhow() {
        fn through_anyhow() -> anyhow::Result<u32> {
            let v: Result<u32, HarpsgError> = Err(HarpsgError::InvalidJob("ranks".into()));
            let v = v?;
            Ok(v + 1)
        }
        let e = through_anyhow().unwrap_err();
        assert!(format!("{e:#}").contains("invalid job: ranks"));
    }
}
