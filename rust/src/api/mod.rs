//! # `harpsg::api` — the public facade
//!
//! The one entry point downstream users should need. Everything the crate
//! can do — load a graph, validate a counting job, run it distributed,
//! observe progress, serialize the result — is reachable from four types:
//!
//! * [`Session`] — owns a loaded [`crate::graph::Graph`] plus its
//!   amortized per-rank-count exchange setup (partition, request lists,
//!   neighbor-pair plans) and, optionally, the XLA runtime. Build once,
//!   run many jobs: the multi-template sweeps of the figure harness and
//!   the GFD example all reuse one plan per rank count.
//! * [`CountJob`] — a validated, typed job built with
//!   [`CountJob::builder`]; inconsistent configs (zero ranks, task sizes
//!   on per-vertex modes, out-of-range ring group sizes, …) are rejected
//!   at `build()` with a [`HarpsgError`], never at run time.
//! * [`JobReport`] — the serializable result: estimate, model clock,
//!   per-subtemplate comm decisions, thread stats, memory peaks; emits
//!   JSON ([`JobReport::to_json_string`], what `harpsg count --json`
//!   prints) and CSV ([`JobReport::series_of`]).
//! * [`Progress`] — observer callbacks (per iteration, per subtemplate,
//!   per exchange step) for CLIs and services that stream status.
//!
//! ```no_run
//! use harpsg::api::{CountJob, Session};
//! use harpsg::coordinator::ModeSelect;
//! use harpsg::graph::Dataset;
//!
//! let session = Session::new(Dataset::TwitterS.generate(20_000));
//! let jobs: Vec<_> = ["u3-1", "u5-2", "u7-2", "u10-2"]
//!     .iter()
//!     .map(|name| {
//!         CountJob::of_builtin(name)
//!             .unwrap()
//!             .ranks(8)
//!             .mode(ModeSelect::AdaptiveLb)
//!             .iterations(8)
//!             .build()
//!             .unwrap()
//!     })
//!     .collect();
//! // one partition + request-list build serves all four templates
//! for report in session.count_batch(&jobs).unwrap() {
//!     println!("{:8} {:.3e}", report.template, report.estimate);
//! }
//! ```

pub mod error;
pub mod job;
pub mod progress;
pub mod report;
pub mod session;

pub use error::HarpsgError;
pub use job::{CountJob, CountJobBuilder};
pub use progress::{Progress, StderrProgress};
pub use report::JobReport;
pub use session::{PartitionKind, Session, SessionOptions};
