//! Static-analysis gate over the crate sources.
//!
//! The concurrency refactor that introduced [`crate::util::shim`] comes
//! with three whole-tree invariants that `rustc` cannot enforce on its
//! own. This module is a small, dependency-free checker for them, run in
//! CI as the `analysis-gate` binary and unit-tested here against both the
//! live tree and seeded violations:
//!
//! 1. **Atomics go through the shim.** No file outside `util/shim` may
//!    name `std`/`core` atomics or a memory-ordering constant. Call sites
//!    must use the ordering-free shim API so the model checker sees every
//!    operation and orderings are chosen in exactly one place.
//! 2. **Every `unsafe` site carries a `SAFETY:` comment.** A line comment
//!    stating the proof obligation must sit directly above the statement
//!    containing the `unsafe` token (attributes and the statement's own
//!    continuation lines may intervene; blank lines and completed
//!    statements may not).
//! 3. **Fabric types stay behind the executors.** Only `comm/` (the
//!    fabric trait and both transports — the in-process mailbox and the
//!    process-mode socket mesh) and `coordinator/` (the executors, the
//!    distributed driver, and the `procmode` launcher/worker entry
//!    points) may name a `Fabric` type. Everything else — including the
//!    `harpsg-rank` worker binary, which funnels through
//!    `coordinator::procmode::rank_main` — must go through the executor
//!    layer so delivery stays canonical on every transport. (The matcher
//!    is an identifier-*suffix* check: `FabricKind`, the mode-matrix
//!    config enum, continues past the needle and is deliberately exempt —
//!    the CLI and config layers select a fabric without touching one.)
//! 4. **Frontier bitmaps are built in one place.** Only
//!    `colorcount/frontier` may *construct* a `Frontier` — the struct
//!    literal or the `::full` constructor. Every other module derives
//!    frontiers through the `CountTable::frontier`/`TableStorage::frontier`
//!    accessors (which live inside the frontier module), so the
//!    nonzero-row semantics that pruning's bit-exactness rests on are
//!    defined exactly once. Naming the type (imports, `Option<Frontier>`
//!    parameters) is fine anywhere.
//!
//! The matcher works on comment-stripped lines, so prose mentions of the
//! forbidden names are fine. The needles the checker searches for are
//! assembled at runtime (`concat`) so this file does not flag itself.

use std::fs;
use std::io;
use std::path::Path;

/// One gate violation: which rule fired, where, and the offending text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Path relative to the scanned root, with `/` separators.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// One of [`RULE_ATOMICS`], [`RULE_SAFETY`], [`RULE_FABRIC`],
    /// [`RULE_FRONTIER`].
    pub rule: &'static str,
    pub detail: String,
}

pub const RULE_ATOMICS: &str = "shim-atomics";
pub const RULE_SAFETY: &str = "safety-comment";
pub const RULE_FABRIC: &str = "fabric-access";
pub const RULE_FRONTIER: &str = "frontier-construction";

/// How many lines above an `unsafe` token the `SAFETY:` comment may
/// start, counting the statement's own continuation lines.
const SAFETY_LOOKBACK: usize = 8;

struct Needles {
    sync_atomic: String,
    orderings: Vec<String>,
    unsafe_kw: String,
    safety_tag: String,
    fabric: String,
    frontier: String,
    frontier_ctor: String,
}

impl Needles {
    // Built at runtime so the checker's own source never contains the
    // patterns it hunts for.
    fn new() -> Self {
        Needles {
            sync_atomic: ["::sync", "::atomic"].concat(),
            orderings: ["Relaxed", "SeqCst", "Acquire", "Release", "AcqRel"]
                .iter()
                .map(|v| ["Ordering", "::", v].concat())
                .collect(),
            unsafe_kw: ["un", "safe"].concat(),
            safety_tag: ["SAFE", "TY:"].concat(),
            fabric: ["Fab", "ric"].concat(),
            frontier: ["Fron", "tier"].concat(),
            frontier_ctor: ["::", "full"].concat(),
        }
    }
}

/// The code part of a source line: everything before the first `//`.
/// (Good enough for this tree — no string literal here embeds `//`.)
fn strip_comment(line: &str) -> &str {
    match line.find("//") {
        Some(p) => &line[..p],
        None => line,
    }
}

fn is_comment_line(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

fn is_attr_line(line: &str) -> bool {
    line.trim_start().starts_with("#[")
}

fn is_ident_char(c: Option<char>) -> bool {
    matches!(c, Some(c) if c.is_alphanumeric() || c == '_')
}

/// Whole-word occurrence check: `needle` must not be embedded in a
/// longer identifier.
fn contains_word(hay: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(p) = hay[from..].find(needle) {
        let at = from + p;
        let pre = hay[..at].chars().next_back();
        let post = hay[at + needle.len()..].chars().next();
        if !is_ident_char(pre) && !is_ident_char(post) {
            return true;
        }
        from = at + needle.len();
    }
    false
}

/// Identifier-suffix occurrence check: matches `needle` and any longer
/// identifier ending in it (`Fabric` must catch `ThreadedFabric` too),
/// but not identifiers that merely continue past it.
fn contains_word_suffix(hay: &str, needle: &str) -> bool {
    let mut from = 0;
    while let Some(p) = hay[from..].find(needle) {
        let at = from + p;
        let post = hay[at + needle.len()..].chars().next();
        if !is_ident_char(post) {
            return true;
        }
        from = at + needle.len();
    }
    false
}

/// Construction check for the frontier rule: a word-bounded occurrence
/// of the type name followed (after whitespace) by a struct-literal
/// brace or the `::full` constructor. Type mentions — imports,
/// `Option<…>` parameters, turbofish-free accessor calls — continue past
/// neither and are exempt.
fn constructs_frontier(hay: &str, n: &Needles) -> bool {
    let needle = &n.frontier;
    let mut from = 0;
    while let Some(p) = hay[from..].find(needle.as_str()) {
        let at = from + p;
        let pre = hay[..at].chars().next_back();
        let rest = &hay[at + needle.len()..];
        if !is_ident_char(pre) && !is_ident_char(rest.chars().next()) {
            let after = rest.trim_start();
            if after.starts_with('{') || after.starts_with(n.frontier_ctor.as_str()) {
                return true;
            }
        }
        from = at + needle.len();
    }
    false
}

/// Does a `SAFETY:` comment sit directly above line index `i`?
/// Climbs over comment lines, attributes, and unfinished statement
/// lines (e.g. `let slot =`); stops at blank lines or lines whose code
/// part ends a statement or block (`;`, `{`, `}`).
fn has_safety_comment_above(lines: &[&str], i: usize, n: &Needles) -> bool {
    let lo = i.saturating_sub(SAFETY_LOOKBACK);
    for j in (lo..i).rev() {
        let line = lines[j];
        if line.trim().is_empty() {
            return false;
        }
        if is_comment_line(line) {
            if line.contains(&n.safety_tag) {
                return true;
            }
            continue;
        }
        if is_attr_line(line) {
            continue;
        }
        let code = strip_comment(line).trim_end();
        if code.ends_with(';') || code.ends_with('{') || code.ends_with('}') {
            return false;
        }
        // a continuation line of the statement that holds the token —
        // but it may still carry a trailing SAFETY comment
        if line.contains(&n.safety_tag) {
            return true;
        }
    }
    false
}

fn atomics_whitelisted(file: &str) -> bool {
    file.contains("util/shim")
}

fn fabric_whitelisted(file: &str) -> bool {
    file.starts_with("comm/") || file.starts_with("coordinator/")
}

fn frontier_whitelisted(file: &str) -> bool {
    file.contains("colorcount/frontier")
}

/// Check one file's source. `file` is the root-relative path used both
/// for reporting and for the per-rule whitelists.
pub fn check_source(file: &str, src: &str) -> Vec<Violation> {
    let n = Needles::new();
    let lines: Vec<&str> = src.lines().collect();
    let mut out = Vec::new();
    for (i, raw) in lines.iter().enumerate() {
        let code = strip_comment(raw);
        if code.trim().is_empty() {
            continue;
        }
        let mut push = |rule, detail: String| {
            out.push(Violation {
                file: file.to_string(),
                line: i + 1,
                rule,
                detail,
            })
        };
        if !atomics_whitelisted(file) {
            if code.contains(&n.sync_atomic) {
                push(
                    RULE_ATOMICS,
                    format!("direct {} use; go through util::shim", n.sync_atomic),
                );
            }
            if let Some(o) = n.orderings.iter().find(|o| code.contains(o.as_str())) {
                push(
                    RULE_ATOMICS,
                    format!("explicit {o}; orderings are chosen by util::shim"),
                );
            }
        }
        if contains_word(code, &n.unsafe_kw) && !has_safety_comment_above(&lines, i, &n) {
            push(
                RULE_SAFETY,
                format!("{} block without a {} comment above", n.unsafe_kw, n.safety_tag),
            );
        }
        if !fabric_whitelisted(file) && contains_word_suffix(code, &n.fabric) {
            push(
                RULE_FABRIC,
                format!(
                    "{} access outside comm/ and coordinator/; use the executor layer",
                    n.fabric
                ),
            );
        }
        if !frontier_whitelisted(file) && constructs_frontier(code, &n) {
            push(
                RULE_FRONTIER,
                format!(
                    "{} constructed outside colorcount/frontier; derive it \
                     through the table accessors",
                    n.frontier
                ),
            );
        }
    }
    out
}

fn walk(dir: &Path, files: &mut Vec<std::path::PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, files)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            files.push(path);
        }
    }
    Ok(())
}

/// Check every `.rs` file under `root` (normally the crate's `src/`).
/// Files are visited in sorted order so reports are deterministic.
pub fn check_tree(root: &Path) -> io::Result<Vec<Violation>> {
    let mut files = Vec::new();
    walk(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for path in files {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = fs::read_to_string(&path)?;
        out.extend(check_source(&rel, &src));
    }
    Ok(out)
}

/// Render violations one per line, `file:line [rule] detail`.
pub fn render(violations: &[Violation]) -> String {
    let mut s = String::new();
    for v in violations {
        s.push_str(&format!("{}:{} [{}] {}\n", v.file, v.line, v.rule, v.detail));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    // Seeded sources are assembled at runtime for the same reason the
    // needles are: the gate scans this file too.
    fn kw() -> String {
        ["un", "safe"].concat()
    }

    #[test]
    fn gate_passes_on_the_tree() {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src");
        let v = check_tree(&root).expect("scan src tree");
        assert!(v.is_empty(), "gate violations in tree:\n{}", render(&v));
    }

    #[test]
    fn gate_covers_the_vectorized_kernel_hot_path() {
        // The SIMD combine kernel and its row-block executor are the
        // densest unsafe code in the tree; make sure the gate's pass over
        // them is not vacuous. Each file must (a) pass as written and
        // (b) fail once its SAFETY comments are stripped — proving the
        // gate genuinely sees every unchecked access in the hot path.
        let tag = ["SAFE", "TY:"].concat();
        for rel in ["colorcount/kernel.rs", "colorcount/parallel.rs"] {
            let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
                .join("src")
                .join(rel);
            let src = std::fs::read_to_string(&path).expect("read hot-path module");
            let v = check_source(rel, &src);
            assert!(v.is_empty(), "{rel} must pass the gate:\n{}", render(&v));
            assert!(
                src.contains(&tag),
                "{rel} must document its {} sites",
                kw()
            );
            let stripped: String = src
                .lines()
                .filter(|l| !l.contains(&tag))
                .map(|l| format!("{l}\n"))
                .collect();
            let v = check_source(rel, &stripped);
            assert!(
                v.iter().any(|v| v.rule == RULE_SAFETY),
                "stripping {} comments from {rel} must trip the gate",
                tag
            );
        }
    }

    #[test]
    fn atomic_import_outside_shim_is_flagged() {
        let src = ["use std", "::sync", "::atomic::AtomicU64;\n"].concat();
        let v = check_source("colorcount/x.rs", &src);
        assert_eq!(v.len(), 1, "{}", render(&v));
        assert_eq!(v[0].rule, RULE_ATOMICS);
        assert_eq!(v[0].line, 1);
        assert!(check_source("util/shim/x.rs", &src).is_empty());
    }

    #[test]
    fn explicit_ordering_is_flagged_but_comments_are_not() {
        let ord = ["Ordering", "::", "Relaxed"].concat();
        let src = format!("fn f(a: &A) {{ a.load({ord}); }}\n");
        let v = check_source("graph.rs", &src);
        assert_eq!(v.len(), 1, "{}", render(&v));
        assert_eq!(v[0].rule, RULE_ATOMICS);
        let commented = format!("// historical note about {ord}\n");
        assert!(check_source("graph.rs", &commented).is_empty());
    }

    #[test]
    fn unsafe_without_safety_comment_is_flagged() {
        let src = format!("{} impl Send for X {{}}\n", kw());
        let v = check_source("sched.rs", &src);
        assert_eq!(v.len(), 1, "{}", render(&v));
        assert_eq!(v[0].rule, RULE_SAFETY);
    }

    #[test]
    fn safety_comment_above_satisfies_the_rule() {
        let tag = ["SAFE", "TY:"].concat();
        let src = format!(
            "// {tag} X holds no thread-affine state.\n{} impl Send for X {{}}\n",
            kw()
        );
        assert!(check_source("sched.rs", &src).is_empty());
    }

    #[test]
    fn safety_comment_climbs_continuation_lines_but_not_statements() {
        let tag = ["SAFE", "TY:"].concat();
        // comment above a multi-line statement: accepted
        let ok = format!(
            "// {tag} window is claimed once.\nlet slot =\n    {} {{ w() }};\n",
            kw()
        );
        assert!(check_source("sched.rs", &ok).is_empty());
        // a completed statement between comment and token: rejected
        let bad = format!(
            "// {tag} window is claimed once.\nlet n = 3;\nlet s = {} {{ w() }};\n",
            kw()
        );
        let v = check_source("sched.rs", &bad);
        assert_eq!(v.len(), 1, "{}", render(&v));
        assert_eq!(v[0].rule, RULE_SAFETY);
        // a blank line between comment and token: rejected
        let blank = format!(
            "// {tag} window is claimed once.\n\nlet s = {} {{ w() }};\n",
            kw()
        );
        assert_eq!(check_source("sched.rs", &blank).len(), 1);
    }

    #[test]
    fn unsafe_inside_identifiers_or_comments_is_ignored() {
        let src = format!("let {}_mode = 3; // {} is discussed here\n", kw(), kw());
        // `unsafe_mode` fails the word-boundary check; the comment is
        // stripped before matching
        assert!(check_source("sched.rs", &src).is_empty());
    }

    #[test]
    fn fabric_outside_comm_and_coordinator_is_flagged() {
        for prefix in ["Threaded", "Socket"] {
            let ty = [prefix, "Fab", "ric"].concat();
            let src = format!("let f = {ty}::connect(2, 1);\n");
            let v = check_source("colorcount/x.rs", &src);
            assert_eq!(v.len(), 1, "{}", render(&v));
            assert_eq!(v[0].rule, RULE_FABRIC);
            assert!(check_source("comm/x.rs", &src).is_empty());
            assert!(check_source("coordinator/x.rs", &src).is_empty());
        }
        // identifiers continuing past the needle are exempt: the CLI's
        // `FabricKind` selects a transport without naming one
        let kind = ["Fab", "ric", "Kind"].concat();
        let src = format!("let k = {kind}::parse(s);\n");
        assert!(check_source("main.rs", &src).is_empty());
        // the worker binary itself must stay clean of transport types
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("src")
            .join("bin")
            .join("harpsg_rank.rs");
        let src = std::fs::read_to_string(&root).expect("read worker binary source");
        assert!(check_source("bin/harpsg_rank.rs", &src).is_empty());
    }

    #[test]
    fn frontier_construction_outside_frontier_module_is_flagged() {
        let ty = ["Fron", "tier"].concat();
        // the two construction vectors: the `::full` constructor and a
        // struct literal
        let ctor = format!("let f = {ty}{}(64);\n", ["::", "full"].concat());
        let lit = format!("let f = {ty} {{ n_rows, words, live }};\n");
        for src in [&ctor, &lit] {
            let v = check_source("coordinator/dist.rs", src);
            assert_eq!(v.len(), 1, "{}", render(&v));
            assert_eq!(v[0].rule, RULE_FRONTIER);
            // the one legal home
            assert!(check_source("colorcount/frontier.rs", src).is_empty());
        }
        // type mentions are not construction: imports, Option params,
        // and accessor calls all pass everywhere
        for ok in [
            format!("use crate::colorcount::{ty};\n"),
            format!("fn g(f: Option<&{ty}>) -> bool {{ f.is_some() }}\n"),
            "let f = table.frontier();\n".to_string(),
        ] {
            assert!(
                check_source("coordinator/dist.rs", &ok).is_empty(),
                "false positive on: {ok}"
            );
        }
    }

    #[test]
    fn render_is_one_line_per_violation() {
        let v = vec![Violation {
            file: "a.rs".into(),
            line: 7,
            rule: RULE_FABRIC,
            detail: "d".into(),
        }];
        assert_eq!(render(&v), "a.rs:7 [fabric-access] d\n");
    }
}
