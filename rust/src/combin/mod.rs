//! Combinatorics substrate for color-coding: binomial coefficients, the
//! colorset index system (a bijection between size-`a` subsets of `k`
//! colors and dense indices `0..C(k,a)`), and precomputed *split tables*
//! that drive the dynamic-programming combine step (Eq. 1 of the paper).
//!
//! Subsets are represented as `u32` bitmasks over at most [`MAX_COLORS`]
//! colors. Ranking uses the combinatorial number system in colex order, so
//! ranks are stable, dense and cheap to compute; unranking is a small
//! greedy loop. Both directions are table-free, but we additionally build a
//! direct `mask -> rank` lookup array when profiling shows it worthwhile
//! (it is — see EXPERIMENTS.md §Perf).

pub mod split;

pub use split::{CheckedSplit, SplitTable};

/// Maximum supported number of colors (the paper scales templates to 15
/// vertices; masks are u32 so anything ≤ 31 works, 16 keeps tables small).
pub const MAX_COLORS: usize = 16;

/// Dense table of binomial coefficients `C(n, r)` for `n, r ≤ MAX_COLORS`.
#[derive(Debug, Clone)]
pub struct Binomial {
    table: [[u64; MAX_COLORS + 1]; MAX_COLORS + 1],
}

impl Binomial {
    pub fn new() -> Self {
        let mut t = [[0u64; MAX_COLORS + 1]; MAX_COLORS + 1];
        for n in 0..=MAX_COLORS {
            t[n][0] = 1;
            for r in 1..=n {
                t[n][r] = t[n - 1][r - 1] + if r <= n - 1 { t[n - 1][r] } else { 0 };
            }
        }
        Binomial { table: t }
    }

    /// `C(n, r)`; 0 when `r > n`.
    #[inline]
    pub fn c(&self, n: usize, r: usize) -> u64 {
        if r > n {
            0
        } else {
            self.table[n][r]
        }
    }
}

impl Default for Binomial {
    fn default() -> Self {
        Self::new()
    }
}

/// The colorset index system for a fixed `(k, a)`: bijection between
/// bitmasks of `a` set bits among the low `k` bits and ranks `0..C(k,a)`,
/// in colex order (mask with smaller highest-differing bit ranks first).
#[derive(Debug, Clone)]
pub struct ColorsetIndexer {
    pub k: usize,
    pub a: usize,
    pub count: usize,
    /// rank -> mask
    masks: Vec<u32>,
    /// mask -> rank (dense over 2^k; u32::MAX for invalid masks)
    ranks: Vec<u32>,
}

impl ColorsetIndexer {
    pub fn new(k: usize, a: usize, binom: &Binomial) -> Self {
        assert!(k <= MAX_COLORS && a <= k, "k={k} a={a} out of range");
        let count = binom.c(k, a) as usize;
        let mut masks = Vec::with_capacity(count);
        let mut ranks = vec![u32::MAX; 1usize << k];
        // Enumerate all a-subsets of [0,k) in colex order: iterate masks in
        // increasing numeric order; numeric order on bitmasks of equal
        // popcount IS colex order.
        if a == 0 {
            masks.push(0);
            ranks[0] = 0;
        } else {
            // Gosper's hack over masks with `a` bits.
            let mut m: u32 = (1u32 << a) - 1;
            let limit: u32 = 1u32 << k;
            while m < limit {
                ranks[m as usize] = masks.len() as u32;
                masks.push(m);
                // next mask with same popcount
                let c = m & m.wrapping_neg();
                let r = m + c;
                if r >= limit || c == 0 {
                    break;
                }
                m = (((r ^ m) >> 2) / c) | r;
            }
        }
        assert_eq!(masks.len(), count, "enumeration disagrees with C(k,a)");
        ColorsetIndexer {
            k,
            a,
            count,
            masks,
            ranks,
        }
    }

    /// rank -> bitmask
    #[inline]
    pub fn mask(&self, rank: usize) -> u32 {
        self.masks[rank]
    }

    /// bitmask -> rank. Panics (debug) on masks of the wrong popcount.
    #[inline]
    pub fn rank(&self, mask: u32) -> usize {
        let r = self.ranks[mask as usize];
        debug_assert_ne!(r, u32::MAX, "mask {mask:#b} not a {}-subset", self.a);
        r as usize
    }

    /// All masks in rank order.
    pub fn iter_masks(&self) -> impl Iterator<Item = u32> + '_ {
        self.masks.iter().copied()
    }
}

/// Rank a mask with the combinatorial number system directly (no tables) —
/// used by tests as an independent oracle for `ColorsetIndexer::rank`.
pub fn rank_colex(mask: u32, binom: &Binomial) -> u64 {
    let mut rank = 0u64;
    let mut seen = 0usize;
    for bit in 0..32 {
        if mask & (1 << bit) != 0 {
            seen += 1;
            rank += binom.c(bit as usize, seen);
        }
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn binomial_known_values() {
        let b = Binomial::new();
        assert_eq!(b.c(0, 0), 1);
        assert_eq!(b.c(5, 2), 10);
        assert_eq!(b.c(15, 7), 6435);
        assert_eq!(b.c(16, 8), 12870);
        assert_eq!(b.c(12, 6), 924);
        assert_eq!(b.c(3, 5), 0);
    }

    #[test]
    fn binomial_pascal_identity() {
        let b = Binomial::new();
        for n in 1..=MAX_COLORS {
            for r in 1..n {
                assert_eq!(b.c(n, r), b.c(n - 1, r - 1) + b.c(n - 1, r));
            }
        }
    }

    #[test]
    fn indexer_bijection_small() {
        let b = Binomial::new();
        for k in 1..=10 {
            for a in 0..=k {
                let ix = ColorsetIndexer::new(k, a, &b);
                assert_eq!(ix.count as u64, b.c(k, a));
                for r in 0..ix.count {
                    let m = ix.mask(r);
                    assert_eq!(m.count_ones() as usize, a);
                    assert_eq!(ix.rank(m), r);
                }
            }
        }
    }

    #[test]
    fn indexer_matches_colex_oracle() {
        let b = Binomial::new();
        let ix = ColorsetIndexer::new(12, 5, &b);
        for r in 0..ix.count {
            assert_eq!(rank_colex(ix.mask(r), &b), r as u64);
        }
    }

    #[test]
    fn indexer_large_k15() {
        let b = Binomial::new();
        let ix = ColorsetIndexer::new(15, 7, &b);
        assert_eq!(ix.count, 6435);
        // spot-check monotonicity of masks (colex == numeric order)
        for r in 1..ix.count {
            assert!(ix.mask(r) > ix.mask(r - 1));
        }
    }

    #[test]
    fn prop_rank_roundtrip() {
        let b = Binomial::new();
        prop::check("rank_roundtrip", move |g| {
            let k = g.usize_in(1, MAX_COLORS);
            let a = g.usize_in(0, k);
            let ix = ColorsetIndexer::new(k, a, &b);
            let r = g.usize_in(0, ix.count - 1);
            if ix.rank(ix.mask(r)) == r {
                Ok(())
            } else {
                Err(format!("k={k} a={a} r={r}"))
            }
        });
    }
}
