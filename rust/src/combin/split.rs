//! Split tables: the precomputed index pairs that drive the DP combine.
//!
//! For a subtemplate `Ti` of size `a` split into a passive child `Ti'` of
//! size `a1` and an active child `Ti''` of size `a2 = a - a1`, the combine
//! for a color set `S` (|S| = a) enumerates all ways to give `a1` of S's
//! colors to `Ti'` and the rest to `Ti''`:
//!
//! ```text
//! C(v, Ti, S) = Σ_{u ∈ N(v)} Σ_{S1 ⊂ S, |S1|=a1} C(v, Ti', S1) · C(u, Ti'', S\S1)
//! ```
//!
//! `SplitTable` stores, for every rank `s` of S in `C(k,a)` and every one of
//! the `C(a, a1)` splits `j`, the pair of child ranks
//! `(rank_{k,a1}(S1), rank_{k,a2}(S\S1))`, flattened row-major so the hot
//! loop is a linear scan. This is exactly the table the L1 Pallas kernel
//! receives as its `t0`/`t1` operands.

use super::{Binomial, ColorsetIndexer};

#[derive(Debug, Clone)]
pub struct SplitTable {
    pub k: usize,
    /// |Ti|
    pub a: usize,
    /// |Ti'| (passive child, keeps the root)
    pub a1: usize,
    /// |Ti''| (active child)
    pub a2: usize,
    /// number of color sets = C(k, a)
    pub n_sets: usize,
    /// splits per set = C(a, a1)
    pub n_splits: usize,
    /// passive-child ranks, [n_sets * n_splits]
    pub idx1: Vec<u32>,
    /// active-child ranks, [n_sets * n_splits]
    pub idx2: Vec<u32>,
}

impl SplitTable {
    pub fn new(k: usize, a: usize, a1: usize, binom: &Binomial) -> Self {
        assert!(a1 < a && a1 >= 1, "split sizes a={a} a1={a1} invalid");
        let a2 = a - a1;
        let parent = ColorsetIndexer::new(k, a, binom);
        let child1 = ColorsetIndexer::new(k, a1, binom);
        let child2 = ColorsetIndexer::new(k, a2, binom);
        let n_sets = parent.count;
        let n_splits = binom.c(a, a1) as usize;
        let mut idx1 = Vec::with_capacity(n_sets * n_splits);
        let mut idx2 = Vec::with_capacity(n_sets * n_splits);
        for s in 0..n_sets {
            let set = parent.mask(s);
            // enumerate sub-masks of `set` with popcount a1 by iterating
            // all submasks (standard (sub-1)&set walk) and filtering.
            let mut found = 0usize;
            let mut sub = set;
            loop {
                if sub.count_ones() as usize == a1 {
                    idx1.push(child1.rank(sub) as u32);
                    idx2.push(child2.rank(set & !sub) as u32);
                    found += 1;
                }
                if sub == 0 {
                    break;
                }
                sub = (sub - 1) & set;
            }
            debug_assert_eq!(found, n_splits);
        }
        SplitTable {
            k,
            a,
            a1,
            a2,
            n_sets,
            n_splits,
            idx1,
            idx2,
        }
    }

    /// Row view for set-rank `s`: the `(idx1, idx2)` pairs of its splits.
    #[inline]
    pub fn row(&self, s: usize) -> (&[u32], &[u32]) {
        let lo = s * self.n_splits;
        let hi = lo + self.n_splits;
        (&self.idx1[lo..hi], &self.idx2[lo..hi])
    }

    /// Bytes held by this table (for memory accounting).
    pub fn bytes(&self) -> u64 {
        (self.idx1.len() + self.idx2.len()) as u64 * 4
    }
}

/// A [`SplitTable`] validated once against the operand widths of a
/// combine: every `idx1` entry is `< n_passive` and every `idx2` entry is
/// `< n_agg`, and the flattened index vectors have exactly
/// `n_sets * n_splits` entries. The contraction kernels
/// (`colorcount::engine::contract_row` and the SIMD variant) take this
/// type instead of a raw `&SplitTable`, so their per-element
/// `get_unchecked` gathers are justified by a checked construction — a
/// malformed table panics here, once, in release builds too, instead of
/// being UB in the hot loop.
pub struct CheckedSplit<'a> {
    split: &'a SplitTable,
    n_passive: usize,
    n_agg: usize,
}

impl<'a> CheckedSplit<'a> {
    /// Validate `split` against the passive-row width `n_passive` and the
    /// aggregation-row width `n_agg`. O(n_sets · n_splits) — once per
    /// combine, amortized over every vertex row it contracts.
    ///
    /// # Panics
    /// When an index vector has the wrong length or any entry is out of
    /// range for the given widths.
    pub fn new(split: &'a SplitTable, n_passive: usize, n_agg: usize) -> Self {
        let flat = split.n_sets * split.n_splits;
        assert!(
            split.idx1.len() == flat && split.idx2.len() == flat,
            "split table index vectors must be n_sets*n_splits = {flat} long \
             (got {} / {})",
            split.idx1.len(),
            split.idx2.len()
        );
        assert!(
            split.idx1.iter().all(|&i| (i as usize) < n_passive),
            "split table idx1 out of range for passive width {n_passive}"
        );
        assert!(
            split.idx2.iter().all(|&i| (i as usize) < n_agg),
            "split table idx2 out of range for aggregation width {n_agg}"
        );
        CheckedSplit {
            split,
            n_passive,
            n_agg,
        }
    }

    #[inline]
    pub fn split(&self) -> &'a SplitTable {
        self.split
    }

    /// Passive-row width the table was validated against.
    #[inline]
    pub fn n_passive(&self) -> usize {
        self.n_passive
    }

    /// Aggregation-row width the table was validated against.
    #[inline]
    pub fn n_agg(&self) -> usize {
        self.n_agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn dims_match_combinatorics() {
        let b = Binomial::new();
        let t = SplitTable::new(5, 3, 1, &b);
        assert_eq!(t.n_sets as u64, b.c(5, 3)); // 10
        assert_eq!(t.n_splits as u64, b.c(3, 1)); // 3
        assert_eq!(t.idx1.len(), 30);
    }

    #[test]
    fn splits_partition_the_set() {
        let b = Binomial::new();
        let t = SplitTable::new(7, 4, 2, &b);
        let parent = ColorsetIndexer::new(7, 4, &b);
        let c1 = ColorsetIndexer::new(7, 2, &b);
        let c2 = ColorsetIndexer::new(7, 2, &b);
        for s in 0..t.n_sets {
            let set = parent.mask(s);
            let (r1, r2) = t.row(s);
            let mut seen = std::collections::HashSet::new();
            for j in 0..t.n_splits {
                let m1 = c1.mask(r1[j] as usize);
                let m2 = c2.mask(r2[j] as usize);
                assert_eq!(m1 | m2, set, "union is the parent set");
                assert_eq!(m1 & m2, 0, "disjoint");
                assert!(seen.insert(m1), "splits distinct");
            }
        }
    }

    #[test]
    fn prop_split_table_invariants() {
        let b = Binomial::new();
        prop::check("split_invariants", move |g| {
            let k = g.usize_in(3, 12);
            let a = g.usize_in(2, k);
            let a1 = g.usize_in(1, a - 1);
            let t = SplitTable::new(k, a, a1, &b);
            let parent = ColorsetIndexer::new(k, a, &b);
            let c1 = ColorsetIndexer::new(k, a1, &b);
            let c2 = ColorsetIndexer::new(k, a - a1, &b);
            let s = g.usize_in(0, t.n_sets - 1);
            let set = parent.mask(s);
            let (r1, r2) = t.row(s);
            for j in 0..t.n_splits {
                let m1 = c1.mask(r1[j] as usize);
                let m2 = c2.mask(r2[j] as usize);
                if m1 | m2 != set || m1 & m2 != 0 {
                    return Err(format!("k={k} a={a} a1={a1} s={s} j={j}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn checked_split_accepts_exact_widths() {
        let b = Binomial::new();
        let t = SplitTable::new(5, 3, 1, &b);
        let cs = CheckedSplit::new(&t, b.c(5, 1) as usize, b.c(5, 2) as usize);
        assert_eq!(cs.n_passive(), 5);
        assert_eq!(cs.n_agg(), 10);
        assert_eq!(cs.split().n_sets, t.n_sets);
    }

    #[test]
    #[should_panic(expected = "idx1 out of range")]
    fn checked_split_rejects_narrow_passive() {
        let b = Binomial::new();
        let t = SplitTable::new(5, 3, 1, &b);
        let _ = CheckedSplit::new(&t, 2, b.c(5, 2) as usize);
    }

    #[test]
    #[should_panic(expected = "idx2 out of range")]
    fn checked_split_rejects_narrow_agg() {
        let b = Binomial::new();
        let t = SplitTable::new(5, 3, 1, &b);
        let _ = CheckedSplit::new(&t, 5, 3);
    }

    #[test]
    #[should_panic(expected = "index vectors")]
    fn checked_split_rejects_truncated_indices() {
        let b = Binomial::new();
        let mut t = SplitTable::new(5, 3, 1, &b);
        t.idx2.pop();
        let _ = CheckedSplit::new(&t, 5, 10);
    }

    #[test]
    fn large_template_table_size() {
        // u15-class tables must stay modest: C(15,7)=6435 sets × C(7,3)=35
        let b = Binomial::new();
        let t = SplitTable::new(15, 7, 3, &b);
        assert_eq!(t.n_sets, 6435);
        assert_eq!(t.n_splits, 35);
        assert!(t.bytes() < 4 << 20, "table under 4 MiB");
    }
}
