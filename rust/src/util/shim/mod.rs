//! Sync-primitive abstraction layer: the one place the crate touches
//! atomics and blocking primitives.
//!
//! Every concurrent component (`coordinator::memory::SharedAccountant`,
//! `comm::mailbox::ThreadedFabric`, the `colorcount::parallel` task
//! counters) goes through these types instead of `std::sync` directly —
//! the static-analysis gate (`crate::analysis`) enforces it. In a normal
//! build the shim compiles to the plain std primitives with relaxed
//! atomic orderings (exactly what the code used before the shim existed:
//! none of the call sites rely on cross-variable ordering, only on the
//! atomicity of each RMW). With `--features model-check` the same API is
//! backed by [`model`], a loom-style deterministic bounded-interleaving
//! explorer: each operation becomes a schedule point, and `Mutex` /
//! `Condvar` are instrumented variants that cooperate with the model
//! scheduler while leaving code outside an exploration on the real
//! primitives.
//!
//! The atomic API is deliberately **ordering-free**: call sites cannot
//! choose an `Ordering`, so the model build can run everything SeqCst
//! (interleaving exploration subsumes weak-memory reordering for these
//! protocols) while the normal build stays relaxed.

#[cfg(feature = "model-check")]
pub mod model;

#[cfg(feature = "model-check")]
pub use model::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

#[cfg(not(feature = "model-check"))]
pub use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};

use std::sync::atomic::Ordering;
use std::sync::atomic::{AtomicU64 as StdAtomicU64, AtomicUsize as StdAtomicUsize};

#[cfg(feature = "model-check")]
const ORD: Ordering = Ordering::SeqCst;
#[cfg(not(feature = "model-check"))]
const ORD: Ordering = Ordering::Relaxed;

/// Schedule point: under the model checker, hand control back to the
/// scheduler before the operation; a no-op otherwise (including for
/// threads that are not part of an active exploration).
#[inline]
fn schedule_point() {
    #[cfg(feature = "model-check")]
    model::yield_if_modeled();
}

/// Ordering-free `u64` atomic. Relaxed in normal builds, SeqCst plus a
/// schedule point per operation under `model-check`.
#[derive(Debug, Default)]
pub struct AtomicU64(StdAtomicU64);

impl AtomicU64 {
    pub const fn new(v: u64) -> Self {
        AtomicU64(StdAtomicU64::new(v))
    }

    #[inline]
    pub fn load(&self) -> u64 {
        schedule_point();
        self.0.load(ORD)
    }

    #[inline]
    pub fn store(&self, v: u64) {
        schedule_point();
        self.0.store(v, ORD);
    }

    /// Add and return the **previous** value.
    #[inline]
    pub fn fetch_add(&self, v: u64) -> u64 {
        schedule_point();
        self.0.fetch_add(v, ORD)
    }

    /// Monotone max and return the **previous** value.
    #[inline]
    pub fn fetch_max(&self, v: u64) -> u64 {
        schedule_point();
        self.0.fetch_max(v, ORD)
    }

    /// Compare-and-swap. Unlike the std `_weak` variant this never fails
    /// spuriously (the model checker needs CAS loops to terminate within
    /// a bounded schedule), so `Err` always carries a genuinely different
    /// current value.
    #[inline]
    pub fn compare_exchange_weak(&self, current: u64, new: u64) -> Result<u64, u64> {
        schedule_point();
        self.0.compare_exchange(current, new, ORD, ORD)
    }
}

/// Ordering-free `usize` atomic (the parallel executor's task counters).
#[derive(Debug, Default)]
pub struct AtomicUsize(StdAtomicUsize);

impl AtomicUsize {
    pub const fn new(v: usize) -> Self {
        AtomicUsize(StdAtomicUsize::new(v))
    }

    #[inline]
    pub fn load(&self) -> usize {
        schedule_point();
        self.0.load(ORD)
    }

    /// Add and return the **previous** value.
    #[inline]
    pub fn fetch_add(&self, v: usize) -> usize {
        schedule_point();
        self.0.fetch_add(v, ORD)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomics_roundtrip() {
        let a = AtomicU64::new(5);
        assert_eq!(a.fetch_add(3), 5);
        assert_eq!(a.load(), 8);
        a.store(2);
        assert_eq!(a.fetch_max(7), 2);
        assert_eq!(a.fetch_max(1), 7);
        assert_eq!(a.compare_exchange_weak(7, 9), Ok(7));
        assert_eq!(a.compare_exchange_weak(7, 11), Err(9));
        let u = AtomicUsize::new(0);
        assert_eq!(u.fetch_add(1), 0);
        assert_eq!(u.load(), 1);
    }

    #[test]
    fn locks_roundtrip() {
        // outside an exploration the shim locks behave like std locks,
        // model-check feature on or off
        let m = Mutex::new(1u32);
        {
            let mut g = m.lock().unwrap();
            *g += 1;
        }
        assert_eq!(*m.lock().unwrap(), 2);
        let cv = Condvar::new();
        let g = m.lock().unwrap();
        let (g, t) = cv
            .wait_timeout(g, std::time::Duration::from_millis(1))
            .unwrap();
        assert!(t.timed_out());
        drop(g);
    }
}
