//! Deterministic bounded-interleaving model checker (loom/CHESS style),
//! compiled only under `--features model-check`.
//!
//! [`Model::check`] runs a closure many times, once per thread schedule.
//! Threads spawned with [`spawn`] are real OS threads, but a scheduler
//! serializes them: at every shim operation (atomic op, lock, condvar
//! wait) the running thread parks and the coordinator picks who runs
//! next. Exactly one model thread is ever runnable, so each execution is
//! fully deterministic and replayable from the recorded decision vector.
//! The schedule space is explored depth-first with a **preemption bound**
//! (CHESS): a context switch away from a still-runnable thread counts as
//! a preemption, and schedules needing more than the bound are pruned —
//! small bounds are known to expose the overwhelming majority of real
//! concurrency bugs while keeping the space exhaustive-izable.
//!
//! What a failed check reports: the panic message of the failing
//! assertion (or a deadlock diagnosis with every thread's blocked state)
//! plus the thread schedule that produced it.
//!
//! Scope: this explores **interleavings over sequentially consistent
//! operations**. The shim runs all atomics SeqCst in this build, so
//! weak-memory reorderings are out of scope — the protocols under test
//! (accountant, mailbox) claim only interleaving-level invariants.

use std::any::Any;
use std::cell::RefCell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize as StdAtomicUsize, Ordering};
use std::sync::{
    Arc, Condvar as StdCondvar, LockResult, Mutex as StdMutex, MutexGuard as StdMutexGuard,
    PoisonError, TryLockError,
};
use std::time::Duration;

/// Sentinel panic payload used to unwind model threads when an
/// exploration aborts (after a user panic or a deadlock); never reported
/// as a failure itself.
struct ModelAbort;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Status {
    /// runnable, waiting for a grant
    Ready,
    /// currently executing (at most one thread at a time)
    Running,
    /// parked until the mutex with this id is released
    BlockedMutex(usize),
    /// parked until the condvar with this id is notified
    BlockedCondvar(usize),
    /// parked until the thread with this tid finishes
    BlockedJoin(usize),
    Finished,
}

struct SchedState {
    status: Vec<Status>,
    /// per-thread "you may take one step" flags; a grant survives until
    /// the thread consumes it, so grant/park races cannot lose wakeups
    granted: Vec<bool>,
    abort: bool,
    /// first user panic message of this execution
    failure: Option<String>,
}

struct Scheduler {
    state: StdMutex<SchedState>,
    /// the coordinator waits here for the running thread to park
    coord_cv: StdCondvar,
    /// model threads wait here for their grant
    thread_cv: StdCondvar,
    os_handles: StdMutex<Vec<std::thread::JoinHandle<()>>>,
}

struct ThreadCtx {
    sched: Arc<Scheduler>,
    tid: usize,
}

thread_local! {
    static CTX: RefCell<Option<ThreadCtx>> = const { RefCell::new(None) };
}

fn current() -> Option<(Arc<Scheduler>, usize)> {
    CTX.with(|c| c.borrow().as_ref().map(|x| (Arc::clone(&x.sched), x.tid)))
}

/// Schedule point for shim atomics: if the calling thread belongs to an
/// active exploration, park and wait to be rescheduled; otherwise no-op.
pub(crate) fn yield_if_modeled() {
    if let Some((sched, tid)) = current() {
        sched.park(tid, Status::Ready);
    }
}

impl Scheduler {
    fn new() -> Arc<Scheduler> {
        Arc::new(Scheduler {
            state: StdMutex::new(SchedState {
                status: Vec::new(),
                granted: Vec::new(),
                abort: false,
                failure: None,
            }),
            coord_cv: StdCondvar::new(),
            thread_cv: StdCondvar::new(),
            os_handles: StdMutex::new(Vec::new()),
        })
    }

    fn register(&self) -> usize {
        let mut st = self.state.lock().unwrap();
        st.status.push(Status::Ready);
        st.granted.push(false);
        st.status.len() - 1
    }

    /// THE scheduling primitive: move `tid` into `status` (Ready or a
    /// Blocked variant), wake the coordinator, and sleep until granted
    /// the next step. Unwinds with [`ModelAbort`] if the exploration is
    /// aborted while parked.
    fn park(&self, tid: usize, status: Status) {
        let mut st = self.state.lock().unwrap();
        st.status[tid] = status;
        self.coord_cv.notify_all();
        loop {
            if st.abort {
                drop(st);
                std::panic::panic_any(ModelAbort);
            }
            if st.granted[tid] {
                break;
            }
            st = self.thread_cv.wait(st).unwrap();
        }
        st.granted[tid] = false;
        st.status[tid] = Status::Running;
    }

    /// A mutex was released: its waiters become runnable. Called by the
    /// running thread, so no other thread can race the status flips.
    fn mutex_released(&self, id: usize) {
        let mut st = self.state.lock().unwrap();
        for s in st.status.iter_mut() {
            if *s == Status::BlockedMutex(id) {
                *s = Status::Ready;
            }
        }
    }

    /// A condvar was notified: wake all its waiters (or only the
    /// lowest-tid one for `notify_one`). Waking means "runnable and will
    /// re-contend for the mutex" — exactly the std semantics.
    fn cond_notified(&self, id: usize, all: bool) {
        let mut st = self.state.lock().unwrap();
        for s in st.status.iter_mut() {
            if *s == Status::BlockedCondvar(id) {
                *s = Status::Ready;
                if !all {
                    break;
                }
            }
        }
    }

    /// Terminal protocol of a model thread: record the outcome, wake
    /// joiners, and hand control back to the coordinator.
    fn finish(&self, tid: usize, panic: Option<Box<dyn Any + Send>>) {
        let mut st = self.state.lock().unwrap();
        st.status[tid] = Status::Finished;
        for s in st.status.iter_mut() {
            if *s == Status::BlockedJoin(tid) {
                *s = Status::Ready;
            }
        }
        if let Some(p) = panic {
            if !p.is::<ModelAbort>() {
                if st.failure.is_none() {
                    st.failure = Some(panic_message(p.as_ref()));
                }
                st.abort = true;
            }
        }
        self.coord_cv.notify_all();
        self.thread_cv.notify_all();
    }

    /// Coordinator: wait until no thread is running or holds an
    /// unconsumed grant, then classify the quiescent state.
    fn wait_quiescent(&self) -> Quiescent {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.abort {
                return Quiescent::Aborted;
            }
            let busy = st.status.iter().any(|s| *s == Status::Running)
                || st.granted.iter().any(|&g| g);
            if !busy {
                if st.status.iter().all(|s| *s == Status::Finished) {
                    return Quiescent::AllFinished;
                }
                let ready: Vec<usize> = st
                    .status
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| **s == Status::Ready)
                    .map(|(i, _)| i)
                    .collect();
                if ready.is_empty() {
                    return Quiescent::Deadlock(describe(&st.status));
                }
                return Quiescent::Ready(ready);
            }
            st = self.coord_cv.wait(st).unwrap();
        }
    }

    fn grant(&self, tid: usize) {
        let mut st = self.state.lock().unwrap();
        st.granted[tid] = true;
        self.thread_cv.notify_all();
    }

    /// Abort the execution (normal completion included — then it's a
    /// no-op wake), unwind every surviving model thread, and join the OS
    /// threads so no execution leaks into the next schedule.
    fn drain(&self) {
        {
            let mut st = self.state.lock().unwrap();
            st.abort = true;
            self.thread_cv.notify_all();
        }
        let mut st = self.state.lock().unwrap();
        while !st.status.iter().all(|s| *s == Status::Finished) {
            self.thread_cv.notify_all();
            let (g, _) = self
                .coord_cv
                .wait_timeout(st, Duration::from_millis(5))
                .unwrap();
            st = g;
        }
        drop(st);
        let handles: Vec<_> = std::mem::take(&mut *self.os_handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

enum Quiescent {
    AllFinished,
    Aborted,
    Ready(Vec<usize>),
    Deadlock(String),
}

fn panic_message(p: &(dyn Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn describe(status: &[Status]) -> String {
    let parts: Vec<String> = status
        .iter()
        .enumerate()
        .map(|(i, s)| format!("thread {i}: {s:?}"))
        .collect();
    parts.join(", ")
}

/// Handle to a thread spawned with [`spawn`] inside an exploration.
pub struct JoinHandle<T> {
    tid: usize,
    result: Arc<StdMutex<Option<T>>>,
    sched: Arc<Scheduler>,
}

impl<T> JoinHandle<T> {
    /// Block (as a model schedule point) until the thread finishes and
    /// return its value. Panics if the target thread panicked.
    pub fn join(self) -> T {
        let (_, me) = current().expect("JoinHandle::join outside a model exploration");
        loop {
            let finished = {
                let st = self.sched.state.lock().unwrap();
                st.status[self.tid] == Status::Finished
            };
            if finished {
                break;
            }
            self.sched.park(me, Status::BlockedJoin(self.tid));
        }
        let v = self.result.lock().unwrap().take();
        v.expect("model thread panicked; its value was never produced")
    }
}

/// Spawn a thread inside the current exploration. Must be called from a
/// model thread (the `check` closure or one of its descendants).
pub fn spawn<T, F>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let (sched, _) = current().expect("model::spawn outside a model exploration");
    spawn_on(&sched, f)
}

fn spawn_on<T, F>(sched: &Arc<Scheduler>, f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let tid = sched.register();
    let result = Arc::new(StdMutex::new(None));
    let res = Arc::clone(&result);
    let s = Arc::clone(sched);
    let os = std::thread::Builder::new()
        .name(format!("model-{tid}"))
        .spawn(move || {
            CTX.with(|c| {
                *c.borrow_mut() = Some(ThreadCtx {
                    sched: Arc::clone(&s),
                    tid,
                })
            });
            let out = catch_unwind(AssertUnwindSafe(|| {
                // wait for the first grant before touching user code
                s.park(tid, Status::Ready);
                f()
            }));
            CTX.with(|c| *c.borrow_mut() = None);
            match out {
                Ok(v) => {
                    *res.lock().unwrap() = Some(v);
                    s.finish(tid, None);
                }
                Err(p) => s.finish(tid, Some(p)),
            }
        })
        .expect("failed to spawn model thread");
    sched.os_handles.lock().unwrap().push(os);
    JoinHandle {
        tid,
        result,
        sched: Arc::clone(sched),
    }
}

/// One scheduling decision of an execution.
struct Decision {
    /// runnable tids at the decision point (sorted ascending)
    ready: Vec<usize>,
    /// index into `ready` that was granted
    chosen: usize,
    /// tid that was running before this decision (None at the start)
    prev: Option<usize>,
}

enum Outcome {
    Completed,
    Failure(String),
    Deadlock(String),
}

/// Execute the program once under the schedule forced by `forced`
/// (decision indices); beyond the forced prefix, default to running the
/// previous thread (no preemption) or the lowest ready tid.
fn run_once(f: &Arc<dyn Fn() + Send + Sync>, forced: &[usize]) -> (Vec<Decision>, Outcome) {
    let sched = Scheduler::new();
    let root = Arc::clone(f);
    // the root handle is intentionally dropped: run_once observes
    // completion through the scheduler, not through join()
    let _root_handle = spawn_on(&sched, move || root());
    let mut trace: Vec<Decision> = Vec::new();
    let mut prev: Option<usize> = None;
    let deadlock = loop {
        match sched.wait_quiescent() {
            Quiescent::AllFinished | Quiescent::Aborted => break None,
            Quiescent::Deadlock(d) => break Some(d),
            Quiescent::Ready(ready) => {
                let idx = match forced.get(trace.len()) {
                    Some(&i) => {
                        assert!(
                            i < ready.len(),
                            "model replay diverged: the program is not deterministic \
                             under a fixed schedule"
                        );
                        i
                    }
                    None => prev
                        .and_then(|p| ready.iter().position(|&t| t == p))
                        .unwrap_or(0),
                };
                let tid = ready[idx];
                trace.push(Decision {
                    ready,
                    chosen: idx,
                    prev,
                });
                prev = Some(tid);
                sched.grant(tid);
            }
        }
    };
    sched.drain();
    let failure = sched.state.lock().unwrap().failure.take();
    let outcome = if let Some(msg) = failure {
        Outcome::Failure(msg)
    } else if let Some(d) = deadlock {
        Outcome::Deadlock(d)
    } else {
        Outcome::Completed
    };
    (trace, outcome)
}

/// Does choosing `ready[idx]` at this decision preempt a still-runnable
/// previous thread?
fn is_preemptive(d: &Decision, idx: usize) -> bool {
    match d.prev {
        Some(p) => d.ready.contains(&p) && d.ready[idx] != p,
        None => false,
    }
}

/// DFS step: rewrite `forced` to the next unexplored schedule prefix
/// within the preemption bound; false when the space is exhausted.
fn next_schedule(forced: &mut Vec<usize>, trace: &[Decision], bound: usize) -> bool {
    // preemptions consumed by the executed prefix strictly before each depth
    let mut used = Vec::with_capacity(trace.len() + 1);
    used.push(0usize);
    for d in trace {
        used.push(used.last().unwrap() + usize::from(is_preemptive(d, d.chosen)));
    }
    for depth in (0..trace.len()).rev() {
        let d = &trace[depth];
        for idx in d.chosen + 1..d.ready.len() {
            if used[depth] + usize::from(is_preemptive(d, idx)) <= bound {
                forced.clear();
                forced.extend(trace[..depth].iter().map(|x| x.chosen));
                forced.push(idx);
                return true;
            }
        }
    }
    false
}

/// Exploration configuration. `preemption_bound` caps context switches
/// away from a runnable thread per schedule (CHESS-style); raise it for
/// stronger guarantees at combinatorial cost. `max_schedules` is a
/// safety valve: exceeding it panics rather than silently truncating,
/// keeping "exhaustively explored" an honest claim.
pub struct Model {
    max_schedules: usize,
    preemption_bound: usize,
}

impl Default for Model {
    fn default() -> Self {
        Model {
            max_schedules: 100_000,
            preemption_bound: 2,
        }
    }
}

impl Model {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn max_schedules(mut self, n: usize) -> Self {
        self.max_schedules = n;
        self
    }

    pub fn preemption_bound(mut self, n: usize) -> Self {
        self.preemption_bound = n;
        self
    }

    /// Run `f` under every thread schedule within the preemption bound.
    /// Panics — with the failing schedule — on the first assertion
    /// failure or deadlock. Returns the number of schedules explored.
    pub fn check<F>(self, f: F) -> usize
    where
        F: Fn() + Send + Sync + 'static,
    {
        let f: Arc<dyn Fn() + Send + Sync> = Arc::new(f);
        let mut forced: Vec<usize> = Vec::new();
        let mut n = 0usize;
        loop {
            let (trace, outcome) = run_once(&f, &forced);
            n += 1;
            match outcome {
                Outcome::Completed => {}
                Outcome::Failure(msg) => {
                    let sched: Vec<usize> = trace.iter().map(|d| d.ready[d.chosen]).collect();
                    panic!("model check failed on schedule #{n} (thread order {sched:?}): {msg}");
                }
                Outcome::Deadlock(d) => {
                    let sched: Vec<usize> = trace.iter().map(|d| d.ready[d.chosen]).collect();
                    panic!(
                        "model check found a deadlock on schedule #{n} \
                         (thread order {sched:?}): {d}"
                    );
                }
            }
            assert!(
                n < self.max_schedules,
                "model check hit the {}-schedule budget before exhausting the space; \
                 shrink the test configuration or raise max_schedules",
                self.max_schedules
            );
            if !next_schedule(&mut forced, &trace, self.preemption_bound) {
                return n;
            }
        }
    }
}

/// [`Model::check`] with the default bounds.
pub fn check<F>(f: F) -> usize
where
    F: Fn() + Send + Sync + 'static,
{
    Model::new().check(f)
}

// ---------------------------------------------------------------------
// Instrumented lock primitives (drop-in for std::sync via util::shim).
// ---------------------------------------------------------------------

static NEXT_RESOURCE_ID: StdAtomicUsize = StdAtomicUsize::new(0);

fn next_id() -> usize {
    NEXT_RESOURCE_ID.fetch_add(1, Ordering::Relaxed)
}

/// Mutex that registers lock/unlock as model schedule points. Outside an
/// exploration it behaves exactly like `std::sync::Mutex`.
#[derive(Debug)]
pub struct Mutex<T> {
    id: usize,
    inner: StdMutex<T>,
}

impl<T: Default> Default for Mutex<T> {
    fn default() -> Self {
        Mutex::new(T::default())
    }
}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Self {
        Mutex {
            id: next_id(),
            inner: StdMutex::new(value),
        }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match current() {
            None => match self.inner.lock() {
                Ok(g) => Ok(MutexGuard {
                    lock: self,
                    inner: Some(g),
                }),
                Err(pe) => Err(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(pe.into_inner()),
                })),
            },
            Some((sched, tid)) => {
                // schedule point before the acquire attempt, then park on
                // the mutex id until the holder releases
                sched.park(tid, Status::Ready);
                loop {
                    match self.inner.try_lock() {
                        Ok(g) => {
                            return Ok(MutexGuard {
                                lock: self,
                                inner: Some(g),
                            })
                        }
                        Err(TryLockError::WouldBlock) => {
                            sched.park(tid, Status::BlockedMutex(self.id));
                        }
                        Err(TryLockError::Poisoned(pe)) => {
                            return Err(PoisonError::new(MutexGuard {
                                lock: self,
                                inner: Some(pe.into_inner()),
                            }))
                        }
                    }
                }
            }
        }
    }
}

/// Guard for the instrumented [`Mutex`]; releasing it wakes model
/// threads blocked on the same mutex.
pub struct MutexGuard<'a, T> {
    lock: &'a Mutex<T>,
    inner: Option<StdMutexGuard<'a, T>>,
}

impl<'a, T> MutexGuard<'a, T> {
    fn take_std(&mut self) -> StdMutexGuard<'a, T> {
        self.inner.take().expect("guard already released")
    }
}

impl<T> std::ops::Deref for MutexGuard<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard already released")
    }
}

impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard already released")
    }
}

impl<T> Drop for MutexGuard<'_, T> {
    fn drop(&mut self) {
        let held = self.inner.take();
        if held.is_some() {
            // release the real lock before telling the scheduler, so a
            // woken thread's try_lock can succeed immediately
            drop(held);
            if let Some((sched, _)) = current() {
                sched.mutex_released(self.lock.id);
            }
        }
    }
}

/// Condvar that cooperates with the model scheduler. In-model waits
/// never time out: a lost wakeup therefore surfaces as a reported model
/// deadlock instead of being papered over by a timeout.
#[derive(Debug)]
pub struct Condvar {
    id: usize,
    inner: StdCondvar,
}

impl Default for Condvar {
    fn default() -> Self {
        Condvar::new()
    }
}

impl Condvar {
    pub fn new() -> Self {
        Condvar {
            id: next_id(),
            inner: StdCondvar::new(),
        }
    }

    pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        let mut guard = guard;
        match current() {
            None => {
                let std_g = guard.take_std();
                let lock = guard.lock;
                drop(guard); // inert: the std guard has been taken out
                match self.inner.wait(std_g) {
                    Ok(g) => Ok(MutexGuard {
                        lock,
                        inner: Some(g),
                    }),
                    Err(pe) => Err(PoisonError::new(MutexGuard {
                        lock,
                        inner: Some(pe.into_inner()),
                    })),
                }
            }
            Some((sched, tid)) => {
                // atomically (w.r.t. the model: this thread keeps running
                // until it parks) release the mutex and park on the
                // condvar, then re-contend for the mutex once notified
                drop(guard.take_std());
                sched.mutex_released(guard.lock.id);
                sched.park(tid, Status::BlockedCondvar(self.id));
                loop {
                    match guard.lock.inner.try_lock() {
                        Ok(g) => {
                            guard.inner = Some(g);
                            return Ok(guard);
                        }
                        Err(TryLockError::WouldBlock) => {
                            sched.park(tid, Status::BlockedMutex(guard.lock.id));
                        }
                        Err(TryLockError::Poisoned(pe)) => {
                            guard.inner = Some(pe.into_inner());
                            return Err(PoisonError::new(guard));
                        }
                    }
                }
            }
        }
    }

    pub fn wait_timeout<'a, T>(
        &self,
        guard: MutexGuard<'a, T>,
        dur: Duration,
    ) -> LockResult<(MutexGuard<'a, T>, WaitTimeoutResult)> {
        match current() {
            None => {
                let mut guard = guard;
                let std_g = guard.take_std();
                let lock = guard.lock;
                drop(guard);
                match self.inner.wait_timeout(std_g, dur) {
                    Ok((g, t)) => Ok((
                        MutexGuard {
                            lock,
                            inner: Some(g),
                        },
                        WaitTimeoutResult {
                            timed: t.timed_out(),
                        },
                    )),
                    Err(pe) => {
                        let (g, t) = pe.into_inner();
                        Err(PoisonError::new((
                            MutexGuard {
                                lock,
                                inner: Some(g),
                            },
                            WaitTimeoutResult {
                                timed: t.timed_out(),
                            },
                        )))
                    }
                }
            }
            Some(_) => match self.wait(guard) {
                Ok(g) => Ok((g, WaitTimeoutResult { timed: false })),
                Err(pe) => Err(PoisonError::new((
                    pe.into_inner(),
                    WaitTimeoutResult { timed: false },
                ))),
            },
        }
    }

    pub fn notify_all(&self) {
        match current() {
            None => self.inner.notify_all(),
            Some((sched, _)) => sched.cond_notified(self.id, true),
        }
    }

    pub fn notify_one(&self) {
        match current() {
            None => self.inner.notify_one(),
            Some((sched, _)) => sched.cond_notified(self.id, false),
        }
    }
}

/// Mirror of `std::sync::WaitTimeoutResult` for the shim signature.
#[derive(Debug, Clone, Copy)]
pub struct WaitTimeoutResult {
    timed: bool,
}

impl WaitTimeoutResult {
    pub fn timed_out(&self) -> bool {
        self.timed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::shim::AtomicU64;

    #[test]
    fn explores_both_orders_of_two_ops() {
        let n = check(|| {
            let a = Arc::new(AtomicU64::new(0));
            let (a1, a2) = (Arc::clone(&a), Arc::clone(&a));
            let t1 = spawn(move || a1.fetch_add(1));
            let t2 = spawn(move || a2.fetch_add(2));
            let (p1, p2) = (t1.join(), t2.join());
            // each thread observed the other either before or after
            assert!(p1 == 0 || p1 == 2, "t1 saw {p1}");
            assert!(p2 == 0 || p2 == 1, "t2 saw {p2}");
            assert_eq!(a.load(), 3);
        });
        assert!(n >= 2, "only {n} schedules explored");
    }

    #[test]
    fn finds_the_lost_update() {
        // the classic torn read-modify-write: load then store is not
        // atomic, and the explorer must find the schedule that loses one
        // increment — proof the interleaving search is genuine
        let lost = Arc::new(StdMutex::new(false));
        let seen = Arc::clone(&lost);
        check(move || {
            let a = Arc::new(AtomicU64::new(0));
            let (a1, a2) = (Arc::clone(&a), Arc::clone(&a));
            let t1 = spawn(move || {
                let v = a1.load();
                a1.store(v + 1);
            });
            let t2 = spawn(move || {
                let v = a2.load();
                a2.store(v + 1);
            });
            t1.join();
            t2.join();
            let v = a.load();
            assert!(v == 1 || v == 2);
            if v == 1 {
                *seen.lock().unwrap() = true;
            }
        });
        assert!(
            *lost.lock().unwrap(),
            "exploration never produced the lost update"
        );
    }

    #[test]
    fn mutex_provides_mutual_exclusion() {
        check(|| {
            let m = Arc::new(Mutex::new(0u64));
            let (m1, m2) = (Arc::clone(&m), Arc::clone(&m));
            let t1 = spawn(move || {
                let mut g = m1.lock().unwrap();
                let v = *g;
                *g = v + 1;
            });
            let t2 = spawn(move || {
                let mut g = m2.lock().unwrap();
                let v = *g;
                *g = v + 1;
            });
            t1.join();
            t2.join();
            // under a mutex the read-modify-write can never tear
            assert_eq!(*m.lock().unwrap(), 2);
        });
    }

    #[test]
    fn condvar_handoff_completes_in_every_schedule() {
        check(|| {
            let m = Arc::new(Mutex::new(false));
            let cv = Arc::new(Condvar::new());
            let (m1, cv1) = (Arc::clone(&m), Arc::clone(&cv));
            let waiter = spawn(move || {
                let mut g = m1.lock().unwrap();
                while !*g {
                    g = cv1.wait(g).unwrap();
                }
            });
            let (m2, cv2) = (Arc::clone(&m), Arc::clone(&cv));
            let setter = spawn(move || {
                let mut g = m2.lock().unwrap();
                *g = true;
                drop(g);
                cv2.notify_all();
            });
            // if any schedule loses the wakeup, the waiter never
            // finishes and the checker reports a deadlock
            waiter.join();
            setter.join();
        });
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn detects_lock_order_inversion() {
        check(|| {
            let a = Arc::new(Mutex::new(()));
            let b = Arc::new(Mutex::new(()));
            let (a1, b1) = (Arc::clone(&a), Arc::clone(&b));
            let t1 = spawn(move || {
                let _ga = a1.lock().unwrap();
                let _gb = b1.lock().unwrap();
            });
            let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
            let t2 = spawn(move || {
                let _gb = b2.lock().unwrap();
                let _ga = a2.lock().unwrap();
            });
            t1.join();
            t2.join();
        });
    }

    #[test]
    fn replays_are_deterministic() {
        // same forced schedule twice → same decision trace
        let f: Arc<dyn Fn() + Send + Sync> = Arc::new(|| {
            let a = Arc::new(AtomicU64::new(0));
            let (a1, a2) = (Arc::clone(&a), Arc::clone(&a));
            let t1 = spawn(move || {
                a1.fetch_add(1);
            });
            let t2 = spawn(move || {
                a2.fetch_add(1);
            });
            t1.join();
            t2.join();
        });
        let (trace1, _) = run_once(&f, &[]);
        let forced: Vec<usize> = trace1.iter().map(|d| d.chosen).collect();
        let (trace2, _) = run_once(&f, &forced);
        assert_eq!(trace1.len(), trace2.len());
        for (d1, d2) in trace1.iter().zip(&trace2) {
            assert_eq!(d1.ready, d2.ready);
            assert_eq!(d1.chosen, d2.chosen);
        }
    }
}
