//! Deterministic PRNGs used everywhere in the library.
//!
//! Reproducibility is a hard requirement: the distributed engine must
//! produce *bit-identical* colorful counts to the single-rank engine on the
//! same seed, regardless of rank count, communication mode or pipeline
//! settings. We therefore avoid any OS entropy and use counter-based /
//! splittable generators keyed by `(seed, stream)` so every logical site
//! (coloring iteration, RMAT edge, task shuffle, ...) derives its own
//! independent stream.

/// SplitMix64 — used both directly (stream derivation, hashing) and to seed
/// the main generator. Passes BigCrush when used as a 64-bit generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One-shot stateless mix of two words — used to derive per-vertex colors
/// from `(iteration_seed, vertex_id)` so color assignment is independent of
/// graph partitioning (the key to distributed == single-rank determinism).
#[inline]
pub fn mix2(a: u64, b: u64) -> u64 {
    let mut s = a ^ b.wrapping_mul(0x9E3779B97F4A7C15);
    splitmix64(&mut s)
}

/// xoshiro256** — the main sequential generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded via SplitMix64 as recommended by the xoshiro authors.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream for a logical substream id.
    pub fn stream(seed: u64, stream: u64) -> Self {
        Rng::new(mix2(seed, stream))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed).
    pub fn sample_distinct(&mut self, n: u64, k: usize) -> Vec<u64> {
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.below(n);
            if !out.contains(&x) {
                out.push(x);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::stream(42, 0);
        let mut b = Rng::stream(42, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn mix2_partition_independent() {
        // same (seed, vertex) -> same value, different vertex -> different
        assert_eq!(mix2(1, 9), mix2(1, 9));
        assert_ne!(mix2(1, 9), mix2(1, 10));
        assert_ne!(mix2(2, 9), mix2(1, 9));
    }

    #[test]
    fn sample_distinct_unique() {
        let mut r = Rng::new(11);
        let s = r.sample_distinct(100, 10);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 10);
    }
}
