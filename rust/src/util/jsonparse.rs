//! Minimal JSON parser (recursive descent) for the artifact manifest —
//! the vendored crate set has no serde_json. Parses into [`super::Json`].

use super::Json;
use anyhow::{bail, Result};

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected , or ] at byte {}", self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut kvs = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kvs));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kvs));
                }
                _ => bail!("expected , or }} at byte {}", self.i),
            }
        }
    }
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like() {
        let j = parse(r#"{"version":1,"entries":[{"kind":"combine","k":5,"file":"x.hlo.txt"}]}"#)
            .unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("kind").unwrap().as_str(), Some("combine"));
        assert_eq!(e.get("k").unwrap().as_usize(), Some(5));
    }

    #[test]
    fn roundtrips_writer_output() {
        let orig = Json::Obj(vec![
            ("a".into(), Json::Arr(vec![Json::Num(1.5), Json::Null, Json::Bool(true)])),
            ("s".into(), Json::Str("he\"llo\nworld".into())),
        ]);
        let parsed = parse(&orig.render()).unwrap();
        assert_eq!(parsed, orig);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-2.5e3").unwrap().as_f64(), Some(-2500.0));
        assert_eq!(parse("42").unwrap().as_usize(), Some(42));
    }
}
