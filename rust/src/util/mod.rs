//! Small shared utilities: deterministic RNG, human formatting, a tiny
//! JSON writer (no serde facade crate is vendored in this environment),
//! an in-repo property-testing harness, and the sync-primitive shim that
//! every concurrent component routes its atomics and locks through
//! (`shim` — swap in the model checker with `--features model-check`).

pub mod jsonparse;
pub mod prop;
pub mod rng;
pub mod shim;

pub use rng::{mix2, splitmix64, Rng};

/// Format a byte count as a human-readable string.
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format seconds with an adaptive unit.
pub fn human_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

/// Format a large count with thousands separators.
pub fn human_count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// Minimal JSON value + writer. Only what the artifact manifest and metric
/// dumps need; full spec parsing lives in `runtime::manifest`.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(human_secs(2.0), "2.00 s");
        assert_eq!(human_secs(0.002), "2.00 ms");
        assert_eq!(human_secs(2e-6), "2.00 µs");
    }

    #[test]
    fn count_formatting() {
        assert_eq!(human_count(1234567), "1,234,567");
        assert_eq!(human_count(12), "12");
    }

    #[test]
    fn json_roundtrip_shape() {
        let j = Json::Obj(vec![
            ("k".into(), Json::Num(15.0)),
            ("name".into(), Json::Str("u15-1\"x\"".into())),
            ("arr".into(), Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        assert_eq!(
            j.render(),
            r#"{"k":15,"name":"u15-1\"x\"","arr":[true,null]}"#
        );
    }
}
