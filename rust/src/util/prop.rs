//! Minimal in-repo property-testing harness.
//!
//! The vendored crate set for this offline environment does not include
//! `proptest`, so coordinator/engine invariants are checked with this small
//! harness instead: run a property over `CASES` randomly generated inputs
//! derived from a fixed seed; on failure, report the case seed so the exact
//! input can be replayed by constructing `Gen::replay(seed)`.

use super::rng::Rng;

/// Number of cases per property (overridable via `HARPSG_PROP_CASES`).
pub fn cases() -> usize {
    std::env::var("HARPSG_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(48)
}

/// A generation context handed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn replay(case_seed: u64) -> Self {
        Gen {
            rng: Rng::new(case_seed),
            case_seed,
        }
    }

    /// usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.rng.below((hi - lo + 1) as u64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    /// Pick an element from a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// A random vector with a generator closure.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }
}

/// Run `prop` over `cases()` generated inputs. Panics (with the replay seed)
/// on the first failing case.
pub fn check(name: &str, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    let base = 0x5EED_0000u64 ^ name.bytes().fold(0u64, |h, b| h.wrapping_mul(131).wrapping_add(b as u64));
    for i in 0..cases() {
        let case_seed = base.wrapping_add(i as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen::replay(case_seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property `{name}` failed on case {i} (replay seed {case_seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn harness_runs_and_passes() {
        check("sum_commutes", |g| {
            let a = g.usize_in(0, 1000);
            let b = g.usize_in(0, 1000);
            if a + b == b + a {
                Ok(())
            } else {
                Err(format!("{a}+{b} mismatch"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn harness_reports_failure() {
        check("always_fails", |_| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces() {
        let mut g1 = Gen::replay(123);
        let mut g2 = Gen::replay(123);
        for _ in 0..10 {
            assert_eq!(g1.usize_in(0, 1 << 20), g2.usize_in(0, 1 << 20));
        }
    }
}
