//! The figure/table harness: one function per table and figure of the
//! paper's evaluation (§4), each printing the same rows/series the paper
//! reports (DESIGN.md §4 maps IDs → modules → expectations).
//!
//! All experiments run on scaled-down dataset analogs (DESIGN.md §1); the
//! reported times are model-clock (calibrated compute replay + Hockney
//! transfers). Shapes — who wins, by what factor, where crossovers fall —
//! are the reproduction target, not absolute seconds.
//!
//! Every run goes through the `api` facade: each figure opens one
//! [`Session`] per dataset and sweeps templates/modes/ranks against it,
//! so the partition/request-list setup is built once per rank count
//! instead of once per run — the multi-template sweeps (Figs 13–15 touch
//! all ten templates) are where the session amortization pays off.

use crate::api::{CountJob, CountJobBuilder, JobReport, PartitionKind, Session, SessionOptions};
use crate::baseline;
use crate::comm::AdaptivePolicy;
use crate::coordinator::ModeSelect;
use crate::graph::{loader, Dataset, Graph};
use crate::metrics::Series;
use crate::template::{builtin, complexity, BUILTIN_NAMES};

/// Harness context: dataset downscale factor and iteration count.
#[derive(Debug, Clone, Copy)]
pub struct FigureCtx {
    /// extra downscale multiplier on top of each figure's baseline scale
    pub scale_mult: u32,
    pub iters: usize,
    pub seed: u64,
}

impl Default for FigureCtx {
    fn default() -> Self {
        FigureCtx {
            scale_mult: 1,
            iters: 1,
            seed: 42,
        }
    }
}

impl FigureCtx {
    /// Load (or generate + cache) a dataset analog.
    pub fn graph(&self, ds: Dataset, base_scale: u32) -> Graph {
        let scale = base_scale * self.scale_mult;
        let cache = std::path::Path::new("results/cache")
            .join(format!("{}_s{}.bin", ds.abbrev(), scale));
        loader::load_or_generate(&cache, || ds.generate(scale)).expect("dataset cache")
    }

    /// Open a session on a dataset analog (random partition, ctx seed).
    pub fn session(&self, ds: Dataset, base_scale: u32) -> Session {
        self.session_with(ds, base_scale, PartitionKind::Random)
    }

    /// Open a session with an explicit partition strategy (ablation A2).
    pub fn session_with(&self, ds: Dataset, base_scale: u32, partition: PartitionKind) -> Session {
        Session::with_options(
            self.graph(ds, base_scale),
            SessionOptions {
                seed: self.seed,
                partition,
                load_xla: false,
            },
        )
        .expect("session without XLA cannot fail")
    }

    pub fn run(&self, s: &Session, template: &str, mode: ModeSelect, ranks: usize) -> JobReport {
        self.run_cfg(s, template, mode, ranks, |b| b)
    }

    pub fn run_cfg(
        &self,
        s: &Session,
        template: &str,
        mode: ModeSelect,
        ranks: usize,
        tweak: impl FnOnce(CountJobBuilder) -> CountJobBuilder,
    ) -> JobReport {
        let t = builtin(template).expect("builtin template");
        let b = CountJob::builder(t)
            .ranks(ranks)
            .mode(mode)
            .iterations(self.iters)
            .seed(self.seed);
        let job = tweak(b).build().expect("valid figure job");
        s.count(&job).expect("figure job run")
    }
}

/// Table 3: computation intensity of the template family — a pure
/// combinatorial reproduction (exact, no simulation involved).
pub fn table3() -> Vec<Series> {
    let mut s = Series::new(
        "Table 3 — computation intensity of templates (paper: u3-1→2, u5-2→2.8, u7-2→2.9, u10-2→5.3, u12-1→6.0, u12-2→12, u13→22, u14→32, u15-1→60, u15-2→39)",
        &["memory", "computation", "intensity"],
    );
    s.precision = 1;
    for name in BUILTIN_NAMES {
        let c = complexity(&builtin(name).unwrap());
        s.push_row(name, vec![c.memory as f64, c.computation as f64, c.intensity]);
    }
    vec![s]
}

/// Fig 6: Naive implementation, scaling template size on R500K3, 4 → 8
/// ranks: computation vs communication time.
pub fn fig6(ctx: &FigureCtx) -> Vec<Series> {
    let s = ctx.session(Dataset::R500K3, 2000);
    let mut comp = Series::new(
        "Fig 6 — Naive: compute time (model s) on R500K3 (expectation: halves 4→8 ranks for small T)",
        &["4 ranks", "8 ranks"],
    );
    let mut comm = Series::new(
        "Fig 6 — Naive: communication time (model s) (expectation: grows sharply with ranks for u12-2)",
        &["4 ranks", "8 ranks"],
    );
    comp.precision = 4;
    comm.precision = 4;
    for tpl in ["u5-2", "u10-2", "u12-2"] {
        let mut comp_row = Vec::new();
        let mut comm_row = Vec::new();
        for ranks in [4, 8] {
            let r = ctx.run(&s, tpl, ModeSelect::Naive, ranks);
            comp_row.push(r.model.comp);
            comm_row.push(r.model.comm_exposed);
        }
        comp.push_row(tpl, comp_row);
        comm.push_row(tpl, comm_row);
    }
    vec![comp, comm]
}

/// Fig 7: strong scaling Naive vs Pipeline on R500K3 (u10-2, u12-1,
/// u12-2), 4–10 ranks: speedup, total time, compute ratio.
pub fn fig7(ctx: &FigureCtx) -> Vec<Series> {
    let s = ctx.session(Dataset::R500K3, 2000);
    let ranks = [4, 6, 8, 10];
    let cols = ["4 ranks", "6 ranks", "8 ranks", "10 ranks"];
    let mut out = Vec::new();
    for tpl in ["u10-2", "u12-1", "u12-2"] {
        let mut time = Series::new(
            &format!("Fig 7 — {tpl}: total time (model s), Naive vs Pipeline on R500K3"),
            &cols,
        );
        let mut speedup = Series::new(&format!("Fig 7 — {tpl}: speedup vs 4-rank Naive"), &cols);
        let mut ratio = Series::new(
            &format!("Fig 7 — {tpl}: compute fraction of total time"),
            &cols,
        );
        time.precision = 4;
        speedup.precision = 2;
        ratio.precision = 2;
        let mut base = 0.0;
        for (mi, mode) in [ModeSelect::Naive, ModeSelect::Pipeline].iter().enumerate() {
            let mut trow = Vec::new();
            let mut srow = Vec::new();
            let mut rrow = Vec::new();
            for &p in &ranks {
                let r = ctx.run(&s, tpl, *mode, p);
                if mi == 0 && p == ranks[0] {
                    base = r.model.total;
                }
                trow.push(r.model.total);
                srow.push(base / r.model.total);
                rrow.push(1.0 - r.model.comm_ratio());
            }
            time.push_row(mode.name(), trow);
            speedup.push_row(mode.name(), srow);
            ratio.push_row(mode.name(), rrow);
        }
        out.push(speedup);
        out.push(time);
        out.push(ratio);
    }
    out
}

/// Fig 8: overlap ratio ρ of the pipeline — large templates on R500K3,
/// small templates on the big-graph analogs.
pub fn fig8(ctx: &FigureCtx) -> Vec<Series> {
    let ranks_large = [4, 6, 8, 10];
    let s_r500 = ctx.session(Dataset::R500K3, 2000);
    let mut large = Series::new(
        "Fig 8 — mean overlap ratio ρ, Pipeline on R500K3 (expectation: u12-2 ≈ 0.3, u12-1 < 0.1 at scale)",
        &["4 ranks", "6 ranks", "8 ranks", "10 ranks"],
    );
    large.precision = 3;
    for tpl in ["u10-2", "u12-1", "u12-2"] {
        let row = ranks_large
            .iter()
            .map(|&p| ctx.run(&s_r500, tpl, ModeSelect::Pipeline, p).model.mean_rho())
            .collect();
        large.push_row(tpl, row);
    }
    let ranks_small = [10, 15, 20, 25];
    let mut small = Series::new(
        "Fig 8 — mean overlap ratio ρ, Pipeline, small templates on TW/SK/FR analogs (expectation: ρ → 0 beyond ~15 ranks)",
        &["10 ranks", "15 ranks", "20 ranks", "25 ranks"],
    );
    small.precision = 3;
    for (ds, base) in [
        (Dataset::TwitterS, 4000),
        (Dataset::SkS, 8000),
        (Dataset::FriendsterS, 8000),
    ] {
        let s = ctx.session(ds, base);
        for tpl in ["u3-1", "u5-2"] {
            let row = ranks_small
                .iter()
                .map(|&p| ctx.run(&s, tpl, ModeSelect::Pipeline, p).model.mean_rho())
                .collect();
            small.push_row(&format!("{} {}", ds.abbrev(), tpl), row);
        }
    }
    vec![large, small]
}

/// Fig 9: strong scaling of small templates on the large-graph analogs —
/// Adaptive (switches to all-to-all) vs Pipeline.
pub fn fig9(ctx: &FigureCtx) -> Vec<Series> {
    let ranks = [10, 15, 20, 25];
    let cols = ["10 ranks", "15 ranks", "20 ranks", "25 ranks"];
    let mut out = Vec::new();
    for (ds, base) in [
        (Dataset::TwitterS, 4000),
        (Dataset::SkS, 8000),
        (Dataset::FriendsterS, 8000),
    ] {
        let s = ctx.session(ds, base);
        for tpl in ["u3-1", "u5-2"] {
            let mut series = Series::new(
                &format!(
                    "Fig 9 — {} {tpl}: speedup vs 10-rank Pipeline (expectation: Adaptive ≥ Pipeline)",
                    ds.abbrev()
                ),
                &cols,
            );
            series.precision = 2;
            let mut base_t = 0.0;
            for mode in [ModeSelect::Pipeline, ModeSelect::Adaptive] {
                let mut row = Vec::new();
                for &p in &ranks {
                    let r = ctx.run(&s, tpl, mode, p);
                    if mode == ModeSelect::Pipeline && p == ranks[0] {
                        base_t = r.model.total;
                    }
                    row.push(base_t / r.model.total);
                }
                series.push_row(mode.name(), row);
            }
            out.push(series);
        }
    }
    out
}

/// Fig 10: weak scaling (u12-2, RMAT skew 3): workload grows with ranks.
pub fn fig10(ctx: &FigureCtx) -> Vec<Series> {
    let ranks = [4, 6, 8];
    let cols = ["4 ranks", "6 ranks", "8 ranks"];
    let mut time = Series::new(
        "Fig 10 — weak scaling u12-2, RMAT skew 3 (expectation: Pipeline grows ~20% 4→8 ranks; Naive comm ratio passes 50%)",
        &cols,
    );
    let mut ratio = Series::new("Fig 10 — communication fraction of total", &cols);
    time.precision = 4;
    ratio.precision = 2;
    for mode in [ModeSelect::Naive, ModeSelect::Pipeline] {
        let mut trow = Vec::new();
        let mut rrow = Vec::new();
        for &p in &ranks {
            // per-rank-proportional workload: 5 M vertices / 250 M edges
            // per 4 ranks in the paper, downscaled
            let scale = 2000 * ctx.scale_mult;
            let ds = Dataset::WeakRmat {
                n_vertices: (5_000_000 / scale as usize) * p / 4,
                n_edges: (250_000_000 / scale as u64) * p as u64 / 4,
            };
            let s = ctx.session(ds, 1);
            let r = ctx.run(&s, "u12-2", mode, p);
            trow.push(r.model.total);
            rrow.push(r.model.comm_ratio());
        }
        time.push_row(mode.name(), trow);
        ratio.push_row(mode.name(), rrow);
    }
    vec![time, ratio]
}

/// Fig 11: thread-level load balance — skew sweep, thread sweep,
/// concurrency, and the task-size granularity sweep.
pub fn fig11(ctx: &FigureCtx) -> Vec<Series> {
    let mut out = Vec::new();
    // (a) dataset skew sweep: Adaptive vs AdaptiveLB execution time
    let data: Vec<(Dataset, u32)> = vec![
        (Dataset::R250K1, 2000),
        (Dataset::MiamiS, 500),
        (Dataset::OrkutS, 2000),
        (Dataset::R250K3, 2000),
        (Dataset::R250K8, 2000),
    ];
    let mut skew = Series::new(
        "Fig 11a — u12-2 model time (s) by dataset skew (expectation: LB gain ~1x at low skew, up to ~9x at R250K8)",
        &["Adaptive", "AdaptiveLB", "gain"],
    );
    skew.precision = 4;
    for (ds, base) in &data {
        let s = ctx.session(*ds, *base);
        let a = ctx.run(&s, "u12-2", ModeSelect::Adaptive, 4);
        let b = ctx.run(&s, "u12-2", ModeSelect::AdaptiveLb, 4);
        skew.push_row(
            &ds.abbrev(),
            vec![a.model.total, b.model.total, a.model.total / b.model.total],
        );
    }
    out.push(skew);

    // (b) thread sweep on MI (low skew) and R250K8 (high skew)
    let threads = [6, 12, 24, 48];
    let cols = ["6 thr", "12 thr", "24 thr", "48 thr"];
    for (ds, base) in [(Dataset::MiamiS, 500), (Dataset::R250K8, 2000)] {
        let s = ctx.session(ds, base);
        let mut series = Series::new(
            &format!(
                "Fig 11b — {} u12-2 model time (s) vs thread count (expectation: Naive degrades past 24 threads on skewed data; AdaptiveLB flat)",
                ds.abbrev()
            ),
            &cols,
        );
        series.precision = 4;
        for mode in [ModeSelect::Naive, ModeSelect::AdaptiveLb] {
            let row = threads
                .iter()
                .map(|&t| {
                    ctx.run_cfg(&s, "u12-2", mode, 4, |b| b.threads(t))
                        .model
                        .total
                })
                .collect();
            series.push_row(mode.name(), row);
        }
        out.push(series);
    }

    // (c) average thread concurrency (the VTune histograms)
    let mut conc = Series::new(
        "Fig 11c — average concurrent threads of 48 (expectation: ~equal on MI; ~2x gap on R250K8)",
        &["Naive", "AdaptiveLB"],
    );
    conc.precision = 1;
    for (ds, base) in [(Dataset::MiamiS, 500), (Dataset::R250K8, 2000)] {
        let s = ctx.session(ds, base);
        let a = ctx.run(&s, "u12-2", ModeSelect::Naive, 4);
        let b = ctx.run(&s, "u12-2", ModeSelect::AdaptiveLb, 4);
        conc.push_row(
            &ds.abbrev(),
            vec![a.threads.avg_concurrency, b.threads.avg_concurrency],
        );
    }
    out.push(conc);

    // (d) task-size granularity sweep (expectation: optimum ~40–60)
    let sizes = [5u32, 20, 40, 50, 60, 100, 200, 1000];
    let size_cols: Vec<String> = sizes.iter().map(|s| s.to_string()).collect();
    let size_cols: Vec<&str> = size_cols.iter().map(|s| s.as_str()).collect();
    let mut gran = Series::new(
        "Fig 11d — u12-2 model time (s) vs Alg-4 task size (expectation: best between 40 and 60)",
        &size_cols,
    );
    gran.precision = 4;
    for (ds, base) in [(Dataset::R250K3, 2000), (Dataset::R250K8, 2000)] {
        let s = ctx.session(ds, base);
        let row = sizes
            .iter()
            .map(|&ts| {
                ctx.run_cfg(&s, "u12-2", ModeSelect::AdaptiveLb, 4, |b| b.task_size(ts))
                    .model
                    .total
            })
            .collect();
        gran.push_row(&ds.abbrev(), row);
    }
    out.push(gran);
    out
}

/// Fig 12: peak memory per rank, Naive vs Pipeline, u10-2/u12-1/u12-2.
pub fn fig12(ctx: &FigureCtx) -> Vec<Series> {
    let s = ctx.session(Dataset::R500K3, 2000);
    let ranks = [4, 6, 8, 10];
    let cols = ["4 ranks", "6 ranks", "8 ranks", "10 ranks"];
    let mut out = Vec::new();
    for tpl in ["u10-2", "u12-1", "u12-2"] {
        let mut series = Series::new(
            &format!(
                "Fig 12 — {tpl}: peak memory per rank (MiB), Naive vs Pipeline (expectation: 2–5x reduction)"
            ),
            &cols,
        );
        series.precision = 2;
        for mode in [ModeSelect::Naive, ModeSelect::Pipeline] {
            let row = ranks
                .iter()
                .map(|&p| ctx.run(&s, tpl, mode, p).peak_mem() as f64 / (1 << 20) as f64)
                .collect();
            series.push_row(mode.name(), row);
        }
        out.push(series);
    }
    out
}

/// Fig 13: overall AdaptiveLB vs MPI-Fascia on the Twitter analog,
/// templates u3-1 → u15-2 (Fascia OOMs beyond u12-2).
pub fn fig13(ctx: &FigureCtx) -> Vec<Series> {
    let base_scale = 8000;
    let s = ctx.session(Dataset::TwitterS, base_scale);
    let mut series = Series::new(
        "Fig 13 — TW analog: total time (model s), AdaptiveLB vs MPI-Fascia (expectation: parity ≤u7-2, ≥2x at u10-2, ~5x at u12-2, Fascia OOM >u12-2)",
        &["AdaptiveLB", "MPI-Fascia", "speedup"],
    );
    series.precision = 4;
    let scale = base_scale * ctx.scale_mult;
    for tpl in BUILTIN_NAMES {
        let ours = ctx.run(&s, tpl, ModeSelect::AdaptiveLb, 16);
        let t = builtin(tpl).unwrap();
        let fas = baseline::run_fascia(&t, s.graph(), 16, scale, ctx.seed);
        let (ft, sp) = if fas.oom {
            (f64::NAN, f64::NAN) // OOM: Fascia cannot run this template
        } else {
            (fas.model.total, fas.model.total / ours.model.total)
        };
        series.push_row(tpl, vec![ours.model.total, ft, sp]);
    }
    vec![series]
}

/// Fig 14: compute/communication ratio, AdaptiveLB vs Fascia on TW analog.
pub fn fig14(ctx: &FigureCtx) -> Vec<Series> {
    let base_scale = 8000;
    let s = ctx.session(Dataset::TwitterS, base_scale);
    let scale = base_scale * ctx.scale_mult;
    let mut series = Series::new(
        "Fig 14 — TW analog: communication fraction (expectation: Fascia → ~80% at u10-2; AdaptiveLB stays ≈40–50%)",
        &["AdaptiveLB", "MPI-Fascia"],
    );
    series.precision = 2;
    for tpl in ["u3-1", "u5-2", "u10-2", "u12-2"] {
        let ours = ctx.run(&s, tpl, ModeSelect::AdaptiveLb, 16);
        let t = builtin(tpl).unwrap();
        let fas = baseline::run_fascia(&t, s.graph(), 16, scale, ctx.seed);
        let fr = if fas.oom {
            f64::NAN
        } else {
            fas.model.comm_ratio()
        };
        series.push_row(tpl, vec![ours.model.comm_ratio(), fr]);
    }
    vec![series]
}

/// Fig 15: strong scaling AdaptiveLB vs Fascia on the TW analog, 8→16
/// ranks (Fascia cannot run on 8 ranks for large templates: OOM).
pub fn fig15(ctx: &FigureCtx) -> Vec<Series> {
    let base_scale = 8000;
    let s = ctx.session(Dataset::TwitterS, base_scale);
    let scale = base_scale * ctx.scale_mult;
    let ranks = [8, 12, 16];
    let cols = ["8 ranks", "12 ranks", "16 ranks"];
    let mut out = Vec::new();
    for tpl in ["u5-2", "u10-2", "u12-2"] {
        let mut series = Series::new(
            &format!("Fig 15 — {tpl} TW analog: total time (model s); NaN = OOM"),
            &cols,
        );
        series.precision = 4;
        let row_ours = ranks
            .iter()
            .map(|&p| ctx.run(&s, tpl, ModeSelect::AdaptiveLb, p).model.total)
            .collect();
        series.push_row("AdaptiveLB", row_ours);
        let t = builtin(tpl).unwrap();
        let row_fas = ranks
            .iter()
            .map(|&p| {
                let r = baseline::run_fascia(&t, s.graph(), p, scale, ctx.seed);
                if r.oom {
                    f64::NAN
                } else {
                    r.model.total
                }
            })
            .collect();
        series.push_row("MPI-Fascia", row_fas);
        out.push(series);
    }
    out
}

/// Ablation A1 — Adaptive-Group group size: the ring's offsets-per-step
/// parameter g trades steps (W = ceil((P-1)/g)) against per-step volume.
/// The paper fixes g = 1 (Fig 2); this sweep justifies that default for
/// high-intensity templates and shows the all-to-all limit g = P-1.
pub fn abl_group_size(ctx: &FigureCtx) -> Vec<Series> {
    let s = ctx.session(Dataset::R500K3, 2000);
    // feasible rings at P = 16 need 2g+1 ≤ 16 (g ≤ 7); g = 15 is the
    // all-to-all limit — anything between is rejected by validation
    let gs = [1usize, 2, 4, 7, 15];
    let cols: Vec<String> = gs.iter().map(|x| format!("g={x}")).collect();
    let cols: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut series = Series::new(
        "Ablation A1 — u12-2, 16 ranks: total model time (s) vs ring group size g",
        &cols,
    );
    series.precision = 4;
    let row = gs
        .iter()
        .map(|&gsz| run_with_group(&s, 16, gsz, ctx))
        .collect();
    series.push_row("Pipeline", row);
    vec![series]
}

fn run_with_group(s: &Session, ranks: usize, group: usize, ctx: &FigureCtx) -> f64 {
    // group size is plumbed through CountJob::group_size; always pipeline
    // (intensity threshold 0) except at the all-to-all limit g = P-1
    let mut policy = AdaptivePolicy::default();
    policy.intensity_threshold = 0.0;
    let mode = if group >= ranks - 1 {
        ModeSelect::Naive
    } else {
        ModeSelect::Pipeline
    };
    ctx.run_cfg(s, "u12-2", mode, ranks, |b| b.policy(policy).group_size(group))
        .model
        .total
}

/// Ablation A4 — model-driven Adaptive-Group selection: per-subtemplate
/// chosen group sizes with predicted vs measured overlap, against the
/// fixed g = 1 ring and the naive bulk exchange. The sweep should never
/// lose to the fixed shapes on the model clock (it may tie when it picks
/// the same shape everywhere).
pub fn abl_adaptive(ctx: &FigureCtx) -> Vec<Series> {
    let s = ctx.session(Dataset::R500K3, 2000);
    let mut series = Series::new(
        "Ablation A4 — u12-2: model-driven group selection (adaptive) vs fixed g=1 ring vs naive (model s; max g over subs; mean rho over pipelined subs)",
        &["adaptive", "g=1 ring", "naive", "max g", "rho pred", "rho meas"],
    );
    series.precision = 4;
    for ranks in [6usize, 10, 16] {
        let ad = ctx.run_cfg(&s, "u12-2", ModeSelect::Adaptive, ranks, |b| b.adaptive(true));
        let ring = ctx.run(&s, "u12-2", ModeSelect::Pipeline, ranks);
        let naive = ctx.run(&s, "u12-2", ModeSelect::Naive, ranks);
        let piped: Vec<_> = ad.comm_decisions.iter().filter(|d| d.pipelined).collect();
        let max_g = piped.iter().map(|d| d.g).max().unwrap_or(0);
        let mean = |xs: Vec<f64>| {
            if xs.is_empty() {
                f64::NAN
            } else {
                xs.iter().sum::<f64>() / xs.len() as f64
            }
        };
        let rho_pred = mean(piped.iter().map(|d| d.predicted_rho).collect());
        let rho_meas = mean(piped.iter().filter_map(|d| d.measured_rho).collect());
        series.push_row(
            &format!("{ranks} ranks"),
            vec![
                ad.model.total,
                ring.model.total,
                naive.model.total,
                max_g as f64,
                rho_pred,
                rho_meas,
            ],
        );
    }
    vec![series]
}

/// Ablation A2 — vertex partitioning: the Eq-5 analysis assumes random
/// partitioning; contiguous blocks concentrate R-MAT hubs and skew both
/// the exchange volume and the per-rank compute.
pub fn abl_partition(ctx: &FigureCtx) -> Vec<Series> {
    let mut series = Series::new(
        "Ablation A2 — u12-2, 8 ranks, R250K8: random vs block partition",
        &["model time (s)", "peak MiB/rank", "straggler (s)"],
    );
    series.precision = 4;
    for block in [false, true] {
        let partition = if block {
            PartitionKind::Block
        } else {
            PartitionKind::Random
        };
        let s = ctx.session_with(Dataset::R250K8, 2000, partition);
        let res = ctx.run(&s, "u12-2", ModeSelect::AdaptiveLb, 8);
        series.push_row(
            if block { "block" } else { "random" },
            vec![
                res.model.total,
                res.peak_mem() as f64 / (1 << 20) as f64,
                res.model.straggler,
            ],
        );
    }
    vec![series]
}

/// Ablation A3 — interconnect: on a slower network (10 GbE) the adaptive
/// switch point moves (pipelining pays off earlier in template size).
pub fn abl_network(ctx: &FigureCtx) -> Vec<Series> {
    let s = ctx.session(Dataset::R500K3, 2000);
    let mut series = Series::new(
        "Ablation A3 — u10-2 & u12-2, 8 ranks: Naive vs Pipeline on InfiniBand vs 10GbE (model s)",
        &["IB Naive", "IB Pipeline", "10GbE Naive", "10GbE Pipeline"],
    );
    series.precision = 4;
    for tpl in ["u10-2", "u12-2"] {
        let mut row = Vec::new();
        for net in [
            crate::comm::HockneyParams::infiniband(),
            crate::comm::HockneyParams::tengige(),
        ] {
            for mode in [ModeSelect::Naive, ModeSelect::Pipeline] {
                row.push(
                    ctx.run_cfg(&s, tpl, mode, 8, |b| b.net(net))
                        .model
                        .total,
                );
            }
        }
        series.push_row(tpl, row);
    }
    vec![series]
}

/// All figure IDs the harness knows.
pub const ALL_FIGURES: [&str; 15] = [
    "table3",
    "fig6",
    "fig7",
    "fig8",
    "fig9",
    "fig10",
    "fig11",
    "fig12",
    "fig13",
    "fig14",
    "fig15",
    "abl-group-size",
    "abl-adaptive",
    "abl-partition",
    "abl-network",
];

/// Dispatch by ID.
pub fn run_figure(id: &str, ctx: &FigureCtx) -> Option<Vec<Series>> {
    Some(match id {
        "table3" => table3(),
        "fig6" => fig6(ctx),
        "fig7" => fig7(ctx),
        "fig8" => fig8(ctx),
        "fig9" => fig9(ctx),
        "fig10" => fig10(ctx),
        "fig11" => fig11(ctx),
        "fig12" => fig12(ctx),
        "fig13" => fig13(ctx),
        "fig14" => fig14(ctx),
        "fig15" => fig15(ctx),
        "abl-group-size" => abl_group_size(ctx),
        "abl-adaptive" => abl_adaptive(ctx),
        "abl-partition" => abl_partition(ctx),
        "abl-network" => abl_network(ctx),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_reproduces_orderings() {
        let s = &table3()[0];
        let intensity: std::collections::HashMap<&str, f64> = s
            .row_names
            .iter()
            .map(|n| n.as_str())
            .zip(s.cells.iter().map(|c| c[2]))
            .collect();
        assert!(intensity["u12-2"] > 1.6 * intensity["u12-1"]);
        assert!(intensity["u15-1"] > intensity["u15-2"]);
        assert!(intensity["u3-1"] < 3.0);
    }

    #[test]
    fn quick_fig6_shape() {
        // heavily downscaled smoke: naive comm does not shrink with ranks
        // for the big template
        let ctx = FigureCtx {
            scale_mult: 16,
            iters: 1,
            seed: 7,
        };
        let series = fig6(&ctx);
        assert_eq!(series.len(), 2);
        let comm = &series[1];
        let u12 = comm.row_names.iter().position(|n| n == "u12-2").unwrap();
        assert!(
            comm.cells[u12][1] > comm.cells[u12][0] * 0.5,
            "u12-2 naive comm should not shrink much with more ranks: {:?}",
            comm.cells[u12]
        );
    }

    #[test]
    fn dispatch_knows_all_ids() {
        let ctx = FigureCtx {
            scale_mult: 64,
            iters: 1,
            seed: 3,
        };
        assert!(run_figure("table3", &ctx).is_some());
        assert!(run_figure("nope", &ctx).is_none());
    }
}
