//! # harpsg — Pipelined Adaptive-Group Subgraph Counting
//!
//! A from-scratch reproduction of *"High-Performance Massive Subgraph
//! Counting using Pipelined Adaptive-Group Communication"* (Chen, Peng,
//! Ossen, Vullikanti, Marathe, Jiang, Qiu — 2018): distributed approximate
//! treelet counting by color-coding, scaled with
//!
//! * **Adaptive-Group communication** — the all-to-all count exchange is
//!   decoupled into `W` ring-ordered steps with an on-the-fly switch back
//!   to all-to-all for low-intensity templates (`comm`),
//! * a **pipeline design** interleaving per-step computation with the next
//!   step's communication and bounding peak intermediate memory
//!   (`pipeline`, `coordinator::memory`),
//! * **neighbor-list partitioning** for thread-level load balance
//!   (`sched`).
//!
//! The crate is the L3 coordinator of a three-layer stack: the DP combine
//! hot spot is also authored as a JAX + Pallas kernel (`python/compile`),
//! AOT-lowered to HLO text and executed from Rust via PJRT (`runtime`).
//!
//! See `DESIGN.md` for the system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! **Start at [`api`].** `api::Session` + `api::CountJob` +
//! `api::JobReport` are the supported public surface: sessions amortize
//! graph setup across templates, jobs are validated at build time, and
//! reports serialize to JSON/CSV. The modules below it (`coordinator`,
//! `comm`, `colorcount`, …) are the engine room — stable enough to read,
//! but their types are wired together for you by the facade.

#![allow(
    clippy::too_many_arguments,
    clippy::needless_range_loop,
    clippy::field_reassign_with_default
)]

pub mod analysis;
pub mod api;
pub mod baseline;
pub mod colorcount;
pub mod combin;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod figures;
pub mod graph;
pub mod metrics;
pub mod pipeline;
pub mod runtime;
pub mod sched;
pub mod template;
pub mod util;
