//! The artifact manifest: which AOT-compiled HLO modules exist and the
//! fixed shapes each was lowered with (written by `python/compile/aot.py`).

use crate::util::jsonparse;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArtifactKind {
    Combine,
    Fused,
}

#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub kind: ArtifactKind,
    pub template: String,
    pub file: PathBuf,
    pub k: usize,
    pub a: usize,
    pub a1: usize,
    pub a2: usize,
    pub c1: usize,
    pub c2: usize,
    pub n_sets: usize,
    pub n_splits: usize,
    pub block: usize,
    /// fused modules only: halo width (active-row count)
    pub halo: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
        let j = jsonparse::parse(&text)?;
        let version = j.get("version").and_then(|v| v.as_usize()).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(|v| v.as_arr())
            .context("manifest missing `entries`")?
        {
            let get = |k: &str| -> Result<usize> {
                e.get(k)
                    .and_then(|v| v.as_usize())
                    .with_context(|| format!("entry missing `{k}`"))
            };
            let kind = match e.get("kind").and_then(|v| v.as_str()) {
                Some("combine") => ArtifactKind::Combine,
                Some("fused") => ArtifactKind::Fused,
                other => bail!("unknown artifact kind {other:?}"),
            };
            entries.push(ManifestEntry {
                kind,
                template: e
                    .get("template")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
                file: dir.join(e.get("file").and_then(|v| v.as_str()).context("file")?),
                k: get("k")?,
                a: get("a")?,
                a1: get("a1")?,
                a2: get("a2")?,
                c1: get("c1")?,
                c2: get("c2")?,
                n_sets: get("n_sets")?,
                n_splits: get("n_splits")?,
                block: get("block")?,
                halo: e.get("halo").and_then(|v| v.as_usize()),
            });
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    /// Find the combine artifact for a `(k, a, a1)` split shape.
    pub fn find_combine(&self, k: usize, a: usize, a1: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == ArtifactKind::Combine && e.k == k && e.a == a && e.a1 == a1)
    }

    /// True when every combine shape of the template named `t` is covered.
    pub fn covers_template(&self, shapes: &[(usize, usize, usize)]) -> bool {
        shapes
            .iter()
            .all(|&(k, a, a1)| self.find_combine(k, a, a1).is_some())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("harpsg_manifest").join(name);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_minimal_manifest() {
        let d = tmpdir("ok");
        std::fs::write(
            d.join("manifest.json"),
            r#"{"version":1,"entries":[
              {"kind":"combine","template":"u3-1","file":"c.hlo.txt",
               "k":3,"a":2,"a1":1,"a2":1,"c1":3,"c2":3,
               "n_sets":3,"n_splits":2,"block":128}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&d).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find_combine(3, 2, 1).unwrap();
        assert_eq!(e.block, 128);
        assert!(m.find_combine(3, 3, 1).is_none());
    }

    #[test]
    fn missing_manifest_errors_helpfully() {
        let d = tmpdir("missing");
        let err = Manifest::load(&d.join("nope")).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn rejects_wrong_version() {
        let d = tmpdir("badver");
        std::fs::write(d.join("manifest.json"), r#"{"version":9,"entries":[]}"#).unwrap();
        assert!(Manifest::load(&d).is_err());
    }

    #[test]
    fn loads_real_artifacts_if_present() {
        // integration: the repo's own artifacts (built by `make artifacts`)
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find_combine(5, 5, 1).is_some(), "u5-2 root combine");
            for e in &m.entries {
                assert!(e.file.exists(), "artifact file {:?}", e.file);
            }
        }
    }
}
