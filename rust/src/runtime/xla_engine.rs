//! The XLA-backed combine engine: loads the AOT artifacts
//! (`artifacts/*.hlo.txt`, lowered once by `python/compile/aot.py`),
//! compiles them on the PJRT CPU client, and serves the DP contraction
//! from the coordinator's hot path. Python is never involved at runtime —
//! the Rust binary is self-contained once artifacts exist.
//!
//! The real PJRT path needs the external `xla` bindings crate, which is
//! not vendored in this offline environment, so it is gated behind the
//! `pjrt` cargo feature. Without the feature a stub with the identical
//! public API compiles instead: `XlaRuntime::load*` fails with a clear
//! message and `XlaCombine::contract_touched` falls back to the native
//! combine, so callers (CLI, examples, tests) never need their own cfg.

#[cfg(feature = "pjrt")]
mod imp {
    use crate::colorcount::{CombineScratch, Count, CountTable};
    use crate::combin::SplitTable;
    use crate::runtime::manifest::Manifest;
    use anyhow::{Context, Result};
    use std::collections::HashMap;
    use std::path::Path;
    use std::sync::Mutex;

    /// One compiled combine executable plus its lowered shapes.
    struct LoadedCombine {
        exe: xla::PjRtLoadedExecutable,
        block: usize,
        c1: usize,
        c2: usize,
        n_sets: usize,
        n_splits: usize,
        /// cached split-table literals, keyed by the table's identity
        /// (k, a, a1) — rebuilt only when the split changes
        tables: Mutex<Option<((usize, usize, usize), xla::Literal, xla::Literal)>>,
    }

    /// PJRT runtime holding all compiled artifacts.
    pub struct XlaRuntime {
        pub manifest: Manifest,
        combines: HashMap<(usize, usize, usize), LoadedCombine>,
        pub platform: String,
    }

    impl XlaRuntime {
        /// Load + compile every combine artifact in `dir`.
        pub fn load(dir: &Path) -> Result<XlaRuntime> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
            let platform = client.platform_name();
            let mut combines = HashMap::new();
            for e in &manifest.entries {
                if e.kind != crate::runtime::manifest::ArtifactKind::Combine {
                    continue;
                }
                let proto = xla::HloModuleProto::from_text_file(
                    e.file.to_str().context("artifact path not UTF-8")?,
                )
                .with_context(|| format!("parse HLO text {:?}", e.file))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compile {:?}", e.file))?;
                combines.insert(
                    (e.k, e.a, e.a1),
                    LoadedCombine {
                        exe,
                        block: e.block,
                        c1: e.c1,
                        c2: e.c2,
                        n_sets: e.n_sets,
                        n_splits: e.n_splits,
                        tables: Mutex::new(None),
                    },
                );
            }
            Ok(XlaRuntime {
                manifest,
                combines,
                platform,
            })
        }

        /// Load from the default `artifacts/` next to the crate root.
        pub fn load_default() -> Result<XlaRuntime> {
            let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
            Self::load(&dir)
        }

        pub fn has_combine(&self, k: usize, a: usize, a1: usize) -> bool {
            self.combines.contains_key(&(k, a, a1))
        }

        /// Run one padded combine block through PJRT:
        /// passive [block, c1], agg [block, c2] -> [block, n_sets].
        fn run_block(
            &self,
            lc: &LoadedCombine,
            split: &SplitTable,
            passive: &[f32],
            agg: &[f32],
        ) -> Result<Vec<f32>> {
            let p_lit = xla::Literal::vec1(passive).reshape(&[lc.block as i64, lc.c1 as i64])?;
            let a_lit = xla::Literal::vec1(agg).reshape(&[lc.block as i64, lc.c2 as i64])?;
            // build (or reuse) the split-table literals
            let key = (split.k, split.a, split.a1);
            let mut guard = lc.tables.lock().unwrap();
            if guard.as_ref().map(|(k, _, _)| *k) != Some(key) {
                let t0: Vec<i32> = split.idx1.iter().map(|&x| x as i32).collect();
                let t1: Vec<i32> = split.idx2.iter().map(|&x| x as i32).collect();
                let dims = [lc.n_sets as i64, lc.n_splits as i64];
                *guard = Some((
                    key,
                    xla::Literal::vec1(&t0).reshape(&dims)?,
                    xla::Literal::vec1(&t1).reshape(&dims)?,
                ));
            }
            let (_, t0_lit, t1_lit) = guard.as_ref().unwrap();
            let result = lc.exe.execute::<xla::Literal>(&[
                p_lit,
                a_lit,
                t0_lit.clone(),
                t1_lit.clone(),
            ])?[0][0]
                .to_literal_sync()?;
            // lowered with return_tuple=True -> unwrap the 1-tuple
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }
    }

    /// Combine backend plugged into `DistributedRunner` when
    /// `EngineKind::Xla` is selected: consumes the aggregation scratch in
    /// padded blocks through the PJRT executable and accumulates into `out`.
    pub struct XlaCombine {
        pub rt: std::sync::Arc<XlaRuntime>,
    }

    impl XlaCombine {
        pub fn new(rt: std::sync::Arc<XlaRuntime>) -> Self {
            XlaCombine { rt }
        }

        /// Drop-in replacement for `colorcount::contract_touched`, returning
        /// the same unit count. Falls back to the native path when no artifact
        /// covers the split shape (documented behaviour: artifacts ship for
        /// the small-template manifest).
        pub fn contract_touched(
            &self,
            out: &mut CountTable,
            passive: &CountTable,
            split: &SplitTable,
            scratch: &mut CombineScratch,
        ) -> u64 {
            let Some(lc) = self.rt.combines.get(&(split.k, split.a, split.a1)) else {
                return crate::colorcount::contract_touched(out, passive, split, scratch);
            };
            debug_assert_eq!(lc.n_sets, split.n_sets);
            debug_assert_eq!(lc.n_splits, split.n_splits);
            let block = lc.block;
            let touched: Vec<u32> = scratch.touched_rows().to_vec();
            let mut units = 0u64;
            for chunk in touched.chunks(block) {
                // gather padded passive + agg blocks
                let mut p_blk = vec![0f32; block * lc.c1];
                let mut a_blk = vec![0f32; block * lc.c2];
                for (r, &v) in chunk.iter().enumerate() {
                    p_blk[r * lc.c1..(r + 1) * lc.c1].copy_from_slice(passive.row(v as usize));
                    a_blk[r * lc.c2..(r + 1) * lc.c2].copy_from_slice(scratch.agg_row(v as usize));
                }
                let res = self
                    .rt
                    .run_block(lc, split, &p_blk, &a_blk)
                    .expect("PJRT combine execution");
                for (r, &v) in chunk.iter().enumerate() {
                    let orow = out.row_mut(v as usize);
                    let src = &res[r * lc.n_sets..(r + 1) * lc.n_sets];
                    for (o, &x) in orow.iter_mut().zip(src) {
                        *o += x as Count;
                    }
                }
                units += (chunk.len() * lc.n_sets * lc.n_splits) as u64;
            }
            scratch.finish();
            units
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use crate::colorcount::{aggregate_batch, RowsRef};
        use crate::combin::Binomial;
        use std::sync::Arc;

        fn runtime() -> Option<Arc<XlaRuntime>> {
            XlaRuntime::load_default().ok().map(Arc::new)
        }

        #[test]
        fn xla_combine_matches_native() {
            let Some(rt) = runtime() else {
                eprintln!("skipping: artifacts not built");
                return;
            };
            assert!(rt.has_combine(5, 3, 1), "u5-2 shape in manifest");
            let binom = Binomial::new();
            let split = SplitTable::new(5, 3, 1, &binom);
            let n = 40;
            let c1 = 5;
            let c2 = binom.c(5, 2) as usize;
            let mut passive = CountTable::zeros(n, c1);
            let mut active = CountTable::zeros(n, c2);
            for (i, x) in passive.data.iter_mut().enumerate() {
                *x = ((i * 3) % 7) as f32;
            }
            for (i, x) in active.data.iter_mut().enumerate() {
                *x = ((i * 5) % 11) as f32;
            }
            let pairs: Vec<(u32, u32)> = (0..n as u32)
                .flat_map(|v| [(v, (v + 1) % n as u32), (v, (v + 7) % n as u32)])
                .collect();

            let run = |xla: bool| -> CountTable {
                let mut out = CountTable::zeros(n, split.n_sets);
                let mut scratch = CombineScratch::new(n, c2);
                scratch.begin(c2);
                aggregate_batch(&mut scratch, RowsRef::dense(&active), pairs.iter().copied());
                if xla {
                    let xc = XlaCombine::new(rt.clone());
                    xc.contract_touched(&mut out, &passive, &split, &mut scratch);
                } else {
                    crate::colorcount::contract_touched(&mut out, &passive, &split, &mut scratch);
                }
                out
            };
            let native = run(false);
            let xla = run(true);
            for (a, b) in native.data.iter().zip(&xla.data) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }

        #[test]
        fn missing_shape_falls_back() {
            let Some(rt) = runtime() else {
                eprintln!("skipping: artifacts not built");
                return;
            };
            // k=12 shapes are not in the manifest — must silently use native
            let binom = Binomial::new();
            let split = SplitTable::new(12, 3, 1, &binom);
            assert!(!rt.has_combine(12, 3, 1));
            let mut out = CountTable::zeros(4, split.n_sets);
            let passive = CountTable::zeros(4, binom.c(12, 1) as usize);
            let active = CountTable::zeros(4, binom.c(12, 2) as usize);
            let mut scratch = CombineScratch::new(4, active.n_sets);
            scratch.begin(active.n_sets);
            aggregate_batch(
                &mut scratch,
                RowsRef::dense(&active),
                [(0u32, 1u32)].into_iter(),
            );
            let xc = XlaCombine::new(rt);
            let units = xc.contract_touched(&mut out, &passive, &split, &mut scratch);
            assert!(units > 0);
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::colorcount::{CombineScratch, CountTable};
    use crate::combin::SplitTable;
    use crate::runtime::manifest::Manifest;
    use anyhow::{bail, Result};
    use std::path::Path;
    use std::sync::Arc;

    /// Stub runtime compiled when the `pjrt` feature is off: loading always
    /// fails so callers take their documented "artifacts unavailable" path.
    pub struct XlaRuntime {
        pub manifest: Manifest,
        pub platform: String,
    }

    impl XlaRuntime {
        pub fn load(_dir: &Path) -> Result<XlaRuntime> {
            bail!(
                "harpsg was built without the `pjrt` feature; \
                 the XLA/PJRT engine is unavailable (rebuild with \
                 `--features pjrt` and the xla bindings crate)"
            )
        }

        pub fn load_default() -> Result<XlaRuntime> {
            Self::load(Path::new("artifacts"))
        }

        pub fn has_combine(&self, _k: usize, _a: usize, _a1: usize) -> bool {
            false
        }
    }

    /// Stub combine backend: always the native contraction, bit-identical
    /// to `colorcount::contract_touched` by construction.
    pub struct XlaCombine {
        pub rt: Arc<XlaRuntime>,
    }

    impl XlaCombine {
        pub fn new(rt: Arc<XlaRuntime>) -> Self {
            XlaCombine { rt }
        }

        pub fn contract_touched(
            &self,
            out: &mut CountTable,
            passive: &CountTable,
            split: &SplitTable,
            scratch: &mut CombineScratch,
        ) -> u64 {
            crate::colorcount::contract_touched(out, passive, split, scratch)
        }
    }
}

pub use imp::{XlaCombine, XlaRuntime};
