//! PJRT runtime: artifact manifest loading (`manifest`) and the XLA-backed
//! combine engine (`xla_engine`) that executes the AOT-lowered JAX/Pallas
//! modules from the coordinator's hot path. Start-to-finish flow:
//! `python/compile/aot.py` (build time, once) → `artifacts/*.hlo.txt` →
//! `XlaRuntime::load` → `XlaCombine::contract_touched` (request path).

pub mod manifest;
pub mod xla_engine;

pub use manifest::{ArtifactKind, Manifest, ManifestEntry};
pub use xla_engine::{XlaCombine, XlaRuntime};
