//! A minimal, dependency-free stand-in for the `anyhow` crate, vendored so
//! the workspace builds fully offline. It implements exactly the subset the
//! `harpsg` crate uses:
//!
//! * [`Error`] — an opaque error value holding a context chain;
//! * [`Result`] — `Result<T, Error>` with a defaultable error parameter;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * the [`anyhow!`] and [`bail!`] macros.
//!
//! Formatting follows the real crate closely enough for CLI use: `{}`
//! prints the outermost message, `{:#}` prints the whole chain joined with
//! `": "`. Like the upstream crate, `Error` deliberately does **not**
//! implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::error::Error as StdError;
use std::fmt;

/// An opaque error: the outermost message first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `Result` with a defaultable error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e.into())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(inner().unwrap(), 12);
    }

    #[test]
    fn context_chains_and_formats() {
        let e = io_fail().context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.context("missing flag").unwrap_err();
        assert_eq!(format!("{e}"), "missing flag");
    }

    #[test]
    fn with_context_is_lazy() {
        let ok: Result<u8, std::num::ParseIntError> = "3".parse();
        let got = ok.with_context(|| -> String { panic!("must not evaluate") });
        assert_eq!(got.unwrap(), 3);
    }

    #[test]
    fn macros_build_messages() {
        let a = anyhow!("plain");
        assert_eq!(format!("{a}"), "plain");
        let b = anyhow!("x = {}", 7);
        assert_eq!(format!("{b}"), "x = 7");
        fn bails() -> Result<()> {
            bail!("bad {}", "news");
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "bad news");
    }
}
