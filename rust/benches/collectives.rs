//! Collective-exchange benches: schedule construction, fabric throughput,
//! and the pipeline time algebra at figure-harness sizes.

use harpsg::comm::{Fabric, Packet, Schedule};
use harpsg::metrics::bench;
use harpsg::pipeline::{naive, pipelined, StepTiming};

fn main() {
    println!("== exchange schedules ==");
    bench("Schedule::ring(25, g=1)", || Schedule::ring(25, 1));
    bench("Schedule::all_to_all(25)", || Schedule::all_to_all(25));

    println!("== mailbox fabric ==");
    let rows = vec![1.0f32; 64 * 210]; // 64 remote rows of a C(10,4) table
    bench("fabric 16-rank full exchange (64x210 rows)", || {
        let mut f = Fabric::new(16);
        for p in 0..16 {
            for q in 0..16 {
                if p != q {
                    f.send(Packet::new(p, q, 0, 1, 210, rows.clone()));
                }
            }
        }
        for p in 0..16 {
            std::hint::black_box(f.drain(p));
        }
    });

    println!("== pipeline time algebra ==");
    let timings: Vec<Vec<StepTiming>> = (0..24)
        .map(|w| {
            (0..25)
                .map(|p| StepTiming {
                    comp: 0.01 + 0.0001 * ((w * 7 + p) % 13) as f64,
                    comm: 0.008 + 0.0001 * ((w * 3 + p) % 7) as f64,
                })
                .collect()
        })
        .collect();
    bench("pipelined() 24 steps x 25 ranks", || pipelined(&timings));
    bench("naive() 24 steps x 25 ranks", || naive(&timings));
}
