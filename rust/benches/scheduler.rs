//! Scheduler benches: Alg-4 task generation and the virtual-thread replay
//! across task sizes — the machinery behind Fig 11.

use harpsg::metrics::bench;
use harpsg::sched::{make_tasks, replay, TaskCostModel};
use harpsg::util::Rng;

fn main() {
    // power-law-ish degree distribution with a giant hub (R250K8-like)
    let mut rng = Rng::new(9);
    let mut degs: Vec<u32> = (0..20_000)
        .map(|_| {
            let r = rng.f64();
            (8.0 / (1.0 - r).powf(0.7)) as u32
        })
        .collect();
    degs[0] = 200_000; // the hub

    println!("== Alg-4 task generation (20K vertices + hub) ==");
    for s in [0u32, 50, 500] {
        bench(&format!("make_tasks(s={s})"), || {
            make_tasks(&degs, s, Some(7))
        });
    }

    println!("== virtual-thread replay ==");
    let model = TaskCostModel {
        unit_per_pair: 210.0,
        unit_per_task: 0.0,
        overhead: 400.0,
    };
    for s in [0u32, 50, 500] {
        let tasks = make_tasks(&degs, s, Some(7));
        let costs: Vec<f64> = tasks.iter().map(|t| model.cost(t)).collect();
        let label = format!("replay(48 thr, s={s}, {} tasks)", costs.len());
        bench(&label, || replay(&costs, 48, 24));
        let r = replay(&costs, 48, 24);
        println!(
            "  -> makespan {:.3e} units, util {:.0}%, avg conc {:.1}\n",
            r.makespan,
            100.0 * r.utilization,
            r.avg_concurrency
        );
    }
}
