//! Table 3 bench: regenerates the paper's computation-intensity table
//! (exact combinatorics — the one artifact we reproduce value-for-value)
//! and times the template partition + split-table machinery behind it.

use harpsg::combin::{Binomial, SplitTable};
use harpsg::metrics::bench;
use harpsg::template::{builtin, complexity, partition_template, BUILTIN_NAMES};

fn main() {
    println!("== Table 3 (regenerated) ==");
    println!(
        "{:>8} {:>10} {:>13} {:>10}  (paper intensity)",
        "template", "memory", "computation", "intensity"
    );
    let paper = [
        ("u3-1", 2.0),
        ("u5-2", 2.8),
        ("u7-2", 2.9),
        ("u10-2", 5.3),
        ("u12-1", 6.0),
        ("u12-2", 12.0),
        ("u13", 22.0),
        ("u14", 32.0),
        ("u15-1", 60.0),
        ("u15-2", 39.0),
    ];
    for (name, paper_i) in paper {
        let c = complexity(&builtin(name).unwrap());
        println!(
            "{:>8} {:>10} {:>13} {:>10.1}  ({paper_i})",
            name, c.memory, c.computation, c.intensity
        );
    }

    println!("\n== machinery timings ==");
    for name in BUILTIN_NAMES {
        let t = builtin(name).unwrap();
        bench(&format!("partition_template({name})"), || {
            partition_template(&t)
        });
    }
    let binom = Binomial::new();
    bench("SplitTable::new(15,7,3) [6435x35]", || {
        SplitTable::new(15, 7, 3, &binom)
    });
}
