//! Table-storage bench: combine time and exchanged bytes across the
//! dense / sparse representations at several table densities — the
//! trade-off behind the `Auto` policy's threshold. Sparse aggregation
//! skips zero entries (flops ∝ density) and sparse packets shrink wire
//! bytes by ~density at 8 bytes/entry vs 4 dense; both flip against
//! sparse as density approaches the break-even near 1/2.
//!
//! Run: `cargo bench --bench table_storage` (HARPSG_BENCH_MS tunes the
//! per-case budget).

use harpsg::colorcount::parallel::{combine_batches, PairBatch};
use harpsg::colorcount::{
    encode_rows, CountTable, RowsRef, SparseTable, StorageMode, StoragePolicy, TableStorage,
};
use harpsg::combin::{Binomial, SplitTable};
use harpsg::metrics::bench;

/// A table with a deterministic ~`density` fill.
fn mk_table(n: usize, n_sets: usize, density: f64) -> CountTable {
    let mut t = CountTable::zeros(n, n_sets);
    let period = (1.0 / density.max(1e-6)).round().max(1.0) as usize;
    for (i, x) in t.data.iter_mut().enumerate() {
        if i % period == 0 {
            *x = ((i * 7) % 5) as f32 + 0.5;
        }
    }
    t
}

fn ring_pairs(n: usize, deg: usize) -> Vec<(u32, u32)> {
    (0..n as u32)
        .flat_map(|v| (1..=deg as u32).map(move |d| (v, (v + d) % n as u32)))
        .collect()
}

fn bench_density(k: usize, a: usize, a1: usize, n: usize, density: f64) {
    let binom = Binomial::new();
    let split = SplitTable::new(k, a, a1, &binom);
    let c1 = binom.c(k, a1) as usize;
    let c2 = binom.c(k, a - a1) as usize;
    let passive = mk_table(n, c1, 0.9);
    let active = mk_table(n, c2, density);
    let sp_active = SparseTable::from_dense(&active);
    let pairs = ring_pairs(n, 12);
    let units = pairs.len() as f64 * c2 as f64;

    let label = format!("k{k} a{a} n={n} density={density:.2}");
    let mut out = CountTable::zeros(n, split.n_sets);
    let t_dense = bench(&format!("{label}/combine dense"), || {
        let batch = [PairBatch {
            pairs: &pairs,
            rows: RowsRef::dense(&active),
        }];
        combine_batches(&mut out, RowsRef::dense(&passive), &split, &batch, 0, 1)
    });
    let t_sparse = bench(&format!("{label}/combine sparse"), || {
        let batch = [PairBatch {
            pairs: &pairs,
            rows: RowsRef::sparse(&sp_active),
        }];
        combine_batches(&mut out, RowsRef::dense(&passive), &split, &batch, 0, 1)
    });
    println!(
        "  -> dense {:.2} ns/unit, sparse {:.2} ns/unit ({:.2}x)",
        t_dense * 1e9 / units,
        t_sparse * 1e9 / units,
        t_dense / t_sparse
    );

    // exchanged bytes: encode every row once per representation (the
    // exchange ships request-list subsets; whole-table is the bound)
    let dense_store = TableStorage::Dense(active.clone());
    let sparse_store = TableStorage::Sparse(sp_active.clone());
    let dense_wire = encode_rows(&dense_store, 0..n).wire_bytes();
    let sparse_wire = encode_rows(&sparse_store, 0..n).wire_bytes();
    let auto = StoragePolicy::of(StorageMode::Auto);
    println!(
        "  -> wire: dense {dense_wire} B, sparse {sparse_wire} B ({:.2}x); auto picks {}\n",
        dense_wire as f64 / sparse_wire as f64,
        if auto.wants_sparse(n, c2, sp_active.nnz()) {
            "sparse"
        } else {
            "dense"
        }
    );
}

fn main() {
    println!("== table storage: dense vs sparse across densities ==");
    for density in [0.05, 0.15, 0.35, 0.75] {
        bench_density(10, 5, 1, 2048, density);
    }
    println!("== leaf shape (one-hot rows, k=12) ==");
    bench_density(12, 6, 2, 1024, 1.0 / 12.0);
}
