//! Serial vs parallel combine across `max_task_size` settings — the
//! Alg-4 / Fig-11 trade-off measured on the *real* executor instead of
//! the virtual-thread replay. A skewed (hub-heavy) pair distribution
//! shows why neighbor-list partitioning matters: with per-vertex tasks
//! (`mts=0`) the hub pins one worker; bounded tasks spread it.
//!
//! Run: `cargo bench --bench combine_workers` (HARPSG_BENCH_MS tunes the
//! per-case budget).

use harpsg::colorcount::parallel::{combine_batches, combine_batches_with, PairBatch};
use harpsg::colorcount::{
    aggregate_batch, contract_touched, CombineScratch, CountTable, KernelMode, RowsRef,
};
use harpsg::combin::{Binomial, SplitTable};
use harpsg::metrics::bench;

fn mk_tables(n: usize, c1: usize, c2: usize) -> (CountTable, CountTable) {
    let mut passive = CountTable::zeros(n, c1);
    let mut active = CountTable::zeros(n, c2);
    for (i, x) in passive.data.iter_mut().enumerate() {
        *x = ((i * 7) % 5) as f32;
    }
    for (i, x) in active.data.iter_mut().enumerate() {
        *x = ((i * 3) % 4) as f32;
    }
    (passive, active)
}

/// A hub-heavy workload: `n_hubs` vertices carry `hub_deg` pairs each,
/// the rest a flat `deg` — the degree shape of the paper's social graphs.
fn skewed_pairs(n: usize, deg: usize, n_hubs: usize, hub_deg: usize) -> Vec<(u32, u32)> {
    let mut pairs = Vec::new();
    for v in 0..n as u32 {
        let d = if (v as usize) < n_hubs { hub_deg } else { deg };
        for i in 1..=d as u32 {
            pairs.push((v, (v.wrapping_mul(31).wrapping_add(i * 7)) % n as u32));
        }
    }
    pairs
}

fn bench_shape(label: &str, k: usize, a: usize, a1: usize, n: usize) {
    let binom = Binomial::new();
    let split = SplitTable::new(k, a, a1, &binom);
    let c1 = binom.c(k, a1) as usize;
    let c2 = binom.c(k, a - a1) as usize;
    let (passive, active) = mk_tables(n, c1, c2);
    let pairs = skewed_pairs(n, 8, 4, 4 * n);
    let units = pairs.len() as f64 * c2 as f64;

    // serial reference: the scratch-based aggregate + contract
    let mut out = CountTable::zeros(n, split.n_sets);
    let mut scratch = CombineScratch::new(n, c2);
    let t_serial = bench(&format!("{label}/serial"), || {
        scratch.begin(c2);
        aggregate_batch(&mut scratch, RowsRef::dense(&active), pairs.iter().copied());
        contract_touched(&mut out, &passive, &split, &mut scratch);
    });
    println!("  -> {:.2} ns/pair-unit\n", t_serial * 1e9 / units);

    for workers in [1usize, 2, 4, 8] {
        for mts in [0u32, 64, 256] {
            let mut out = CountTable::zeros(n, split.n_sets);
            let t = bench(
                &format!("{label}/exec w={workers} mts={mts}"),
                || {
                    let batch = [PairBatch {
                        pairs: &pairs,
                        rows: RowsRef::dense(&active),
                    }];
                    combine_batches(&mut out, RowsRef::dense(&passive), &split, &batch, mts, workers)
                },
            );
            println!(
                "  -> {:.2} ns/pair-unit, {:.2}x vs serial\n",
                t * 1e9 / units,
                t_serial / t
            );
        }
    }

    // SIMD legs: the fused row-block executor shards by adjacency rows, so
    // `max_task_size` is moot — the grid is kernel x workers only.
    for workers in [1usize, 2, 4, 8] {
        let mut out = CountTable::zeros(n, split.n_sets);
        let t = bench(&format!("{label}/exec w={workers} kernel=simd"), || {
            let batch = [PairBatch {
                pairs: &pairs,
                rows: RowsRef::dense(&active),
            }];
            combine_batches_with(
                &mut out,
                RowsRef::dense(&passive),
                &split,
                &batch,
                0,
                workers,
                KernelMode::Simd,
            )
        });
        println!(
            "  -> {:.2} ns/pair-unit, {:.2}x vs serial\n",
            t * 1e9 / units,
            t_serial / t
        );
    }
}

fn main() {
    println!("== combine executor: serial vs workers x max_task_size ==");
    bench_shape("u5-2-root (k5,a5,a1=1) n=4096", 5, 5, 1, 4096);
    bench_shape("u10-2-mid (k10,a5,a1=1) n=2048", 10, 5, 1, 2048);
    bench_shape("u12-2-mid (k12,a6,a1=2) n=1024", 12, 6, 2, 1024);
}
