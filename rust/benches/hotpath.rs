//! Hot-path benches: the DP combine (aggregate + contract) at the block
//! shapes of u5-2 / u10-2 / u12-2 / u15-1, native vs XLA backends, plus
//! the sparse-storage and vectorized-kernel legs. These are the kernels
//! the end-to-end figures spend >80% of their compute in, and the
//! primary target of EXPERIMENTS.md §Perf.
//!
//! All cases report a throughput figure in Munits/s, where one *unit* is
//! one fused multiply-add in the combine decomposition:
//!   SpMM units   = |pairs| * n_agg        (neighbor-row accumulation)
//!   eMA units    = n * n_sets * n_splits  (split-table contraction)
//! Units/second is shape-independent, so legs at different template
//! shapes and densities are directly comparable.
//!
//! Run: `cargo bench --bench hotpath` (HARPSG_BENCH_MS tunes budgets).

use harpsg::colorcount::parallel::{combine_batches_with, PairBatch};
use harpsg::colorcount::{
    aggregate_batch, contract_touched, CombineScratch, CountTable, KernelMode, RowsRef, SparseTable,
};
use harpsg::combin::{Binomial, SplitTable};
use harpsg::metrics::bench;

fn mk_tables(n: usize, c1: usize, c2: usize) -> (CountTable, CountTable) {
    let mut passive = CountTable::zeros(n, c1);
    let mut active = CountTable::zeros(n, c2);
    for (i, x) in passive.data.iter_mut().enumerate() {
        *x = ((i * 7) % 5) as f32;
    }
    for (i, x) in active.data.iter_mut().enumerate() {
        *x = ((i * 3) % 4) as f32;
    }
    (passive, active)
}

/// Thin a dense table down to roughly `density` non-zero entries per row,
/// keeping a deterministic scatter so sparse rows are realistic (not a
/// prefix) for the row-scratch path.
fn thin_to_density(t: &mut CountTable, density: f64) {
    let keep_every = (1.0 / density.max(1e-9)).round() as usize;
    for (i, x) in t.data.iter_mut().enumerate() {
        if (i * 2654435761) % keep_every.max(1) != 0 {
            *x = 0.0;
        } else if *x == 0.0 {
            *x = 1.0;
        }
    }
}

fn ring_pairs(n: usize, deg: usize) -> Vec<(u32, u32)> {
    (0..n as u32)
        .flat_map(|v| (1..=deg as u32).map(move |d| (v, (v + d) % n as u32)))
        .collect()
}

fn combine_units(pairs: usize, n: usize, c2: usize, split: &SplitTable) -> f64 {
    pairs as f64 * c2 as f64 + n as f64 * (split.n_sets * split.n_splits) as f64
}

fn report_rate(t: f64, units: f64) {
    println!("  -> {:.1} Munits/s ({:.2} ns/unit)\n", units / t / 1e6, t * 1e9 / units);
}

fn bench_combine(label: &str, k: usize, a: usize, a1: usize, n: usize, deg: usize) {
    let binom = Binomial::new();
    let split = SplitTable::new(k, a, a1, &binom);
    let c1 = binom.c(k, a1) as usize;
    let c2 = binom.c(k, a - a1) as usize;
    let (passive, active) = mk_tables(n, c1, c2);
    let pairs = ring_pairs(n, deg);
    let mut out = CountTable::zeros(n, split.n_sets);
    let mut scratch = CombineScratch::new(n, c2);
    let units = combine_units(pairs.len(), n, c2, &split);

    let t_agg = bench(&format!("{label}/aggregate n={n} deg={deg}"), || {
        scratch.begin(c2);
        aggregate_batch(&mut scratch, RowsRef::dense(&active), pairs.iter().copied());
        scratch.finish();
    });
    let t_full = bench(&format!("{label}/agg+contract"), || {
        scratch.begin(c2);
        aggregate_batch(&mut scratch, RowsRef::dense(&active), pairs.iter().copied());
        contract_touched(&mut out, &passive, &split, &mut scratch);
    });
    println!(
        "  -> {:.1} Munits/s ({:.2} ns/unit, agg share {:.0}%)\n",
        units / t_full / 1e6,
        t_full * 1e9 / units,
        100.0 * t_agg / t_full
    );
}

/// Sparse legs: the same combine with the *active* rows stored sparse at a
/// sweep of densities, plus a sparse-passive leg that exercises the
/// touched-set row scratch (`RowScratch`) on every contracted vertex.
fn bench_sparse(label: &str, k: usize, a: usize, a1: usize, n: usize, deg: usize) {
    let binom = Binomial::new();
    let split = SplitTable::new(k, a, a1, &binom);
    let c1 = binom.c(k, a1) as usize;
    let c2 = binom.c(k, a - a1) as usize;
    let pairs = ring_pairs(n, deg);
    let units = combine_units(pairs.len(), n, c2, &split);

    for density in [0.5f64, 0.1, 0.02] {
        let (mut passive, mut active) = mk_tables(n, c1, c2);
        thin_to_density(&mut active, density);
        thin_to_density(&mut passive, density);
        let sp_active = SparseTable::from_dense(&active);
        let sp_passive = SparseTable::from_dense(&passive);

        let mut out = CountTable::zeros(n, split.n_sets);
        let t = bench(
            &format!("{label}/sparse-active d={density}"),
            || {
                let batch = [PairBatch {
                    pairs: &pairs,
                    rows: RowsRef::sparse(&sp_active),
                }];
                combine_batches_with(
                    &mut out,
                    RowsRef::dense(&passive),
                    &split,
                    &batch,
                    0,
                    1,
                    KernelMode::Scalar,
                )
            },
        );
        report_rate(t, units);

        let mut out = CountTable::zeros(n, split.n_sets);
        let t = bench(
            &format!("{label}/sparse-passive d={density}"),
            || {
                let batch = [PairBatch {
                    pairs: &pairs,
                    rows: RowsRef::dense(&active),
                }];
                combine_batches_with(
                    &mut out,
                    RowsRef::sparse(&sp_passive),
                    &split,
                    &batch,
                    0,
                    1,
                    KernelMode::Scalar,
                )
            },
        );
        report_rate(t, units);
    }
}

/// Scalar vs vectorized combine kernel on the wide shapes where the SIMD
/// chunking has lanes to fill (u12 root: n_agg=495; u15 mid: n_agg=1365).
fn bench_kernels(label: &str, k: usize, a: usize, a1: usize, n: usize, deg: usize) {
    let binom = Binomial::new();
    let split = SplitTable::new(k, a, a1, &binom);
    let c1 = binom.c(k, a1) as usize;
    let c2 = binom.c(k, a - a1) as usize;
    let (passive, active) = mk_tables(n, c1, c2);
    let pairs = ring_pairs(n, deg);
    let units = combine_units(pairs.len(), n, c2, &split);

    let mut t_scalar = f64::NAN;
    for kernel in [KernelMode::Scalar, KernelMode::Simd] {
        for workers in [1usize, 4] {
            let mut out = CountTable::zeros(n, split.n_sets);
            let t = bench(
                &format!("{label}/{} w={workers}", kernel.name()),
                || {
                    let batch = [PairBatch {
                        pairs: &pairs,
                        rows: RowsRef::dense(&active),
                    }];
                    combine_batches_with(
                        &mut out,
                        RowsRef::dense(&passive),
                        &split,
                        &batch,
                        0,
                        workers,
                        kernel,
                    )
                },
            );
            if kernel == KernelMode::Scalar && workers == 1 {
                t_scalar = t;
            }
            println!(
                "  -> {:.1} Munits/s ({:.2}x vs scalar w=1)\n",
                units / t / 1e6,
                t_scalar / t
            );
        }
    }
}

/// Frontier-pruning legs: scalar/simd × pruned/unpruned across a row-
/// occupancy sweep (shared with the `bench-report` trajectory bin —
/// see `harpsg::metrics::legs`). Throughput is in Munits/s of the
/// unpruned unit count for both variants, so pruned/unpruned reads as
/// speedup on the same logical work; the acceptance bar is ≥ 1.5× at
/// occupancy ≤ 0.2.
fn bench_pruned() {
    use harpsg::metrics::legs::{default_legs, run_leg};
    let results: Vec<_> = default_legs().iter().map(|s| run_leg(s, 3, 1)).collect();
    for r in &results {
        let twin = results
            .iter()
            .find(|u| !u.pruned && u.kernel == r.kernel && u.occupancy == r.occupancy)
            .map(|u| u.munits_per_s)
            .unwrap_or(f64::NAN);
        println!(
            "bench {:<44} {:>9.1} Munits/s ({:.2}x vs unpruned, {} pairs skipped)",
            r.leg,
            r.munits_per_s,
            r.munits_per_s / twin,
            r.pairs_skipped
        );
    }
}

fn bench_xla_vs_native() {
    let Ok(rt) = harpsg::runtime::XlaRuntime::load_default() else {
        println!("bench xla: artifacts not built, skipping");
        return;
    };
    let rt = std::sync::Arc::new(rt);
    let binom = Binomial::new();
    let split = SplitTable::new(5, 3, 1, &binom);
    let c1 = 5;
    let c2 = binom.c(5, 2) as usize;
    let n = 512;
    let (passive, active) = mk_tables(n, c1, c2);
    let pairs: Vec<(u32, u32)> = (0..n as u32).map(|v| (v, (v + 1) % n as u32)).collect();
    let mut out = CountTable::zeros(n, split.n_sets);
    let mut scratch = CombineScratch::new(n, c2);
    let xc = harpsg::runtime::XlaCombine::new(rt);
    bench("xla-combine k5_a3 n=512 (PJRT)", || {
        scratch.begin(c2);
        aggregate_batch(&mut scratch, RowsRef::dense(&active), pairs.iter().copied());
        xc.contract_touched(&mut out, &passive, &split, &mut scratch);
    });
    bench("native-combine k5_a3 n=512", || {
        scratch.begin(c2);
        aggregate_batch(&mut scratch, RowsRef::dense(&active), pairs.iter().copied());
        contract_touched(&mut out, &passive, &split, &mut scratch);
    });
}

fn main() {
    println!("== hot path: DP combine ==");
    bench_combine("u5-2-root  (k5,a5,a1=1) ", 5, 5, 1, 4096, 16);
    bench_combine("u10-2-mid  (k10,a5,a1=1)", 10, 5, 1, 4096, 16);
    bench_combine("u12-2-mid  (k12,a6,a1=2)", 12, 6, 2, 1024, 16);
    bench_combine("u12-2-root (k12,a12,a1=8)", 12, 12, 8, 1024, 16);
    println!("== sparse storage: density sweep ==");
    bench_sparse("u12-2-mid (k12,a6,a1=2) n=1024", 12, 6, 2, 1024, 16);
    println!("== combine kernel: scalar vs simd ==");
    bench_kernels("u12-2-root (k12,a12,a1=8) n=1024", 12, 12, 8, 1024, 16);
    bench_kernels("u15-1-mid  (k15,a7,a1=3) n=256", 15, 7, 3, 256, 16);
    println!("== frontier pruning: occupancy sweep ==");
    bench_pruned();
    println!("== XLA (PJRT) vs native backend ==");
    bench_xla_vs_native();
}
