//! Hot-path benches: the DP combine (aggregate + contract) at the block
//! shapes of u5-2 / u10-2 / u12-2, native vs XLA backends. These are the
//! kernels the end-to-end figures spend >80% of their compute in, and the
//! primary target of EXPERIMENTS.md §Perf.

use harpsg::colorcount::{aggregate_batch, contract_touched, CombineScratch, CountTable, RowsRef};
use harpsg::combin::{Binomial, SplitTable};
use harpsg::metrics::bench;

fn mk_tables(n: usize, c1: usize, c2: usize) -> (CountTable, CountTable) {
    let mut passive = CountTable::zeros(n, c1);
    let mut active = CountTable::zeros(n, c2);
    for (i, x) in passive.data.iter_mut().enumerate() {
        *x = ((i * 7) % 5) as f32;
    }
    for (i, x) in active.data.iter_mut().enumerate() {
        *x = ((i * 3) % 4) as f32;
    }
    (passive, active)
}

fn bench_combine(label: &str, k: usize, a: usize, a1: usize, n: usize, deg: usize) {
    let binom = Binomial::new();
    let split = SplitTable::new(k, a, a1, &binom);
    let c1 = binom.c(k, a1) as usize;
    let c2 = binom.c(k, a - a1) as usize;
    let (passive, active) = mk_tables(n, c1, c2);
    let pairs: Vec<(u32, u32)> = (0..n as u32)
        .flat_map(|v| (1..=deg as u32).map(move |d| (v, (v + d) % n as u32)))
        .collect();
    let mut out = CountTable::zeros(n, split.n_sets);
    let mut scratch = CombineScratch::new(n, c2);
    let units = pairs.len() as f64 * c2 as f64 + n as f64 * (split.n_sets * split.n_splits) as f64;

    let t_agg = bench(&format!("{label}/aggregate n={n} deg={deg}"), || {
        scratch.begin(c2);
        aggregate_batch(&mut scratch, RowsRef::Dense(&active), pairs.iter().copied());
        scratch.finish();
    });
    let t_full = bench(&format!("{label}/agg+contract"), || {
        scratch.begin(c2);
        aggregate_batch(&mut scratch, RowsRef::Dense(&active), pairs.iter().copied());
        contract_touched(&mut out, &passive, &split, &mut scratch);
    });
    println!(
        "  -> {:.2} ns/unit ({:.0} units/op, agg share {:.0}%)\n",
        t_full * 1e9 / units,
        units,
        100.0 * t_agg / t_full
    );
}

fn bench_xla_vs_native() {
    let Ok(rt) = harpsg::runtime::XlaRuntime::load_default() else {
        println!("bench xla: artifacts not built, skipping");
        return;
    };
    let rt = std::sync::Arc::new(rt);
    let binom = Binomial::new();
    let split = SplitTable::new(5, 3, 1, &binom);
    let c1 = 5;
    let c2 = binom.c(5, 2) as usize;
    let n = 512;
    let (passive, active) = mk_tables(n, c1, c2);
    let pairs: Vec<(u32, u32)> = (0..n as u32).map(|v| (v, (v + 1) % n as u32)).collect();
    let mut out = CountTable::zeros(n, split.n_sets);
    let mut scratch = CombineScratch::new(n, c2);
    let xc = harpsg::runtime::XlaCombine::new(rt);
    bench("xla-combine k5_a3 n=512 (PJRT)", || {
        scratch.begin(c2);
        aggregate_batch(&mut scratch, RowsRef::Dense(&active), pairs.iter().copied());
        xc.contract_touched(&mut out, &passive, &split, &mut scratch);
    });
    bench("native-combine k5_a3 n=512", || {
        scratch.begin(c2);
        aggregate_batch(&mut scratch, RowsRef::Dense(&active), pairs.iter().copied());
        contract_touched(&mut out, &passive, &split, &mut scratch);
    });
}

fn main() {
    println!("== hot path: DP combine ==");
    bench_combine("u5-2-root  (k5,a5,a1=1) ", 5, 5, 1, 4096, 16);
    bench_combine("u10-2-mid  (k10,a5,a1=1)", 10, 5, 1, 4096, 16);
    bench_combine("u12-2-mid  (k12,a6,a1=2)", 12, 6, 2, 1024, 16);
    bench_combine("u12-2-root (k12,a12,a1=8)", 12, 12, 8, 1024, 16);
    println!("== XLA (PJRT) vs native backend ==");
    bench_xla_vs_native();
}
