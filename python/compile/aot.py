"""AOT pipeline: lower the L2 graphs to HLO **text** artifacts.

HLO text — not `.serialize()` — is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids that the xla_extension 0.5.1
behind the Rust `xla` crate rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Emits, for every distinct combine shape of the manifest templates:

    artifacts/combine_k{k}_a{a}_p{a1}_b{B}.hlo.txt

plus one fused (SpMM+combine) demo module, and `artifacts/manifest.json`
describing shapes so the Rust runtime can pick the right executable.
Python runs ONLY here — never on the request path.
"""

import argparse
import json
import os
from math import comb

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.combine import pick_block
from .templates import combine_shapes

# Templates whose combine shapes get AOT artifacts. u3-1/u5-2/u7-2 cover
# the XLA-engine e2e path; larger templates use the native engine (their
# set counts make dense XLA blocks uneconomical on the CPU plugin).
MANIFEST_TEMPLATES = ["u3-1", "u5-2", "u7-2"]

# Fused demo module shape: a 64-vertex tile against a 64-vertex halo.
FUSED_SHAPE = dict(block=64, halo=64, template="u5-2")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_combine(k: int, a: int, a1: int, block: int):
    c1, c2 = comb(k, a1), comb(k, a - a1)
    s, j = comb(k, a), comb(a, a1)
    spec = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)  # noqa: E731
    return jax.jit(model.combine_block).lower(
        spec((block, c1), jnp.float32),
        spec((block, c2), jnp.float32),
        spec((s, j), jnp.int32),
        spec((s, j), jnp.int32),
    )


def lower_fused(k: int, a: int, a1: int, block: int, halo: int):
    c1, c2 = comb(k, a1), comb(k, a - a1)
    s, j = comb(k, a), comb(a, a1)
    spec = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)  # noqa: E731
    return jax.jit(model.fused_block).lower(
        spec((block, halo), jnp.float32),
        spec((halo, c2), jnp.float32),
        spec((block, c1), jnp.float32),
        spec((s, j), jnp.int32),
        spec((s, j), jnp.int32),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--block", type=int, default=0,
                    help="override the vertex-tile size (0 = auto per shape)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = []
    seen = set()
    for name in MANIFEST_TEMPLATES:
        for shape in combine_shapes(name):
            key = (shape.k, shape.a, shape.a1)
            if key in seen:
                continue
            seen.add(key)
            block = args.block or pick_block(
                shape.c1, shape.c2, shape.n_sets, shape.n_splits
            )
            fname = f"combine_k{shape.k}_a{shape.a}_p{shape.a1}_b{block}.hlo.txt"
            text = to_hlo_text(lower_combine(shape.k, shape.a, shape.a1, block))
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(text)
            entries.append(dict(
                kind="combine", template=name, file=fname,
                k=shape.k, a=shape.a, a1=shape.a1, a2=shape.a2,
                c1=shape.c1, c2=shape.c2,
                n_sets=shape.n_sets, n_splits=shape.n_splits, block=block,
            ))
            print(f"wrote {fname} ({len(text)} chars)")

    # fused demo module (L2 composition: SpMM + combine in one HLO)
    fshape = next(s for s in combine_shapes(FUSED_SHAPE["template"])
                  if s.a >= 3)
    fname = (f"fused_k{fshape.k}_a{fshape.a}_p{fshape.a1}"
             f"_b{FUSED_SHAPE['block']}_h{FUSED_SHAPE['halo']}.hlo.txt")
    text = to_hlo_text(lower_fused(
        fshape.k, fshape.a, fshape.a1, FUSED_SHAPE["block"], FUSED_SHAPE["halo"]))
    with open(os.path.join(args.out, fname), "w") as f:
        f.write(text)
    entries.append(dict(
        kind="fused", template=FUSED_SHAPE["template"], file=fname,
        k=fshape.k, a=fshape.a, a1=fshape.a1, a2=fshape.a2,
        c1=fshape.c1, c2=fshape.c2,
        n_sets=fshape.n_sets, n_splits=fshape.n_splits,
        block=FUSED_SHAPE["block"], halo=FUSED_SHAPE["halo"],
    ))
    print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(dict(version=1, entries=entries), f, indent=1)
    print(f"manifest: {len(entries)} entries")


if __name__ == "__main__":
    main()
