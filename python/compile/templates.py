"""Template partitioning, mirrored from `rust/src/template/partition.rs`.

The AOT pipeline needs to know, for each template we ship artifacts for,
the set of distinct `(a, a1)` combine shapes its partition DAG produces —
those determine the fixed shapes of the lowered kernels. The partition
rule must match the Rust side exactly: root the tree at vertex 0, order
children by (descending subtree size, vertex id), split off the *last*
child as the active subtree, deduplicate rooted shapes by AHU canon.
`python/tests/test_templates.py` locks the combos against the values the
Rust test-suite asserts.
"""

from __future__ import annotations

from dataclasses import dataclass
from math import comb
from typing import Dict, List, Optional, Tuple

# Builtin edge lists — keep in sync with rust/src/template/mod.rs.
BUILTIN: Dict[str, Tuple[int, List[Tuple[int, int]]]] = {
    "u3-1": (3, [(0, 1), (1, 2)]),
    "u5-2": (5, [(0, 1), (1, 2), (1, 3), (3, 4)]),
    "u7-2": (7, [(0, 1), (0, 2), (1, 3), (1, 4), (2, 5), (2, 6)]),
    "u10-2": (10, [(0, 1), (0, 2), (0, 3), (0, 4), (0, 5),
                   (1, 6), (1, 7), (1, 8), (1, 9)]),
    "u12-2": (12, [(0, 1), (0, 2), (1, 3), (1, 4), (2, 5), (2, 6),
                   (3, 7), (3, 8), (4, 9), (4, 10), (5, 11)]),
}


@dataclass
class SubTemplate:
    size: int
    passive: Optional[int]
    active: Optional[int]
    canon: str

    @property
    def is_leaf(self) -> bool:
        return self.passive is None


@dataclass
class PartitionDag:
    subs: List[SubTemplate]
    root: int
    order: List[int]


class _RNode:
    __slots__ = ("children",)

    def __init__(self, children: List["_RNode"]):
        self.children = children

    def size(self) -> int:
        return 1 + sum(c.size() for c in self.children)

    def canon(self) -> str:
        return "(" + "".join(sorted(c.canon() for c in self.children)) + ")"


def _build_rooted(n: int, edges: List[Tuple[int, int]]) -> _RNode:
    adj: List[List[int]] = [[] for _ in range(n)]
    for u, v in edges:
        adj[u].append(v)
        adj[v].append(u)

    def rec(v: int, parent: int) -> _RNode:
        kids = []
        for u in adj[v]:
            if u != parent:
                node = rec(u, v)
                kids.append((node.size(), u, node))
        # descending subtree size, ties by vertex id — matches Rust
        kids.sort(key=lambda t: (-t[0], t[1]))
        return _RNode([k[2] for k in kids])

    return rec(0, -1)


def partition_template(n: int, edges: List[Tuple[int, int]]) -> PartitionDag:
    rooted = _build_rooted(n, edges)
    subs: List[SubTemplate] = []
    index: Dict[str, int] = {}
    order: List[int] = []

    def go(node: _RNode) -> int:
        canon = node.canon()
        if canon in index:
            return index[canon]
        if not node.children:
            passive = active = None
        else:
            active = go(node.children[-1])
            passive = go(_RNode(node.children[:-1]))
        i = len(subs)
        subs.append(SubTemplate(node.size(), passive, active, canon))
        index[canon] = i
        order.append(i)
        return i

    root = go(rooted)
    return PartitionDag(subs, root, order)


@dataclass(frozen=True)
class CombineShape:
    """Fixed kernel shape for one (k, a, a1) combine."""

    k: int
    a: int       # |Ti|
    a1: int      # |Ti'| (passive)
    a2: int      # |Ti''| (active)

    @property
    def c1(self) -> int:
        return comb(self.k, self.a1)

    @property
    def c2(self) -> int:
        return comb(self.k, self.a2)

    @property
    def n_sets(self) -> int:
        return comb(self.k, self.a)

    @property
    def n_splits(self) -> int:
        return comb(self.a, self.a1)


def combine_shapes(name: str) -> List[CombineShape]:
    """Distinct combine shapes of a builtin template, in compute order."""
    n, edges = BUILTIN[name]
    dag = partition_template(n, edges)
    seen = set()
    out: List[CombineShape] = []
    for i in dag.order:
        s = dag.subs[i]
        if s.is_leaf:
            continue
        a1 = dag.subs[s.passive].size
        shape = CombineShape(k=n, a=s.size, a1=a1, a2=s.size - a1)
        key = (shape.a, shape.a1)
        if key not in seen:
            seen.add(key)
            out.append(shape)
    return out
