"""L1 Pallas kernels for the color-coding combine hot spot.

`combine` — the per-vertex color-set contraction (the DP's Eq-1 core);
`spmm`    — the neighbor aggregation as a blocked MXU matmul;
`ref`     — pure-jnp oracles both are verified against (pytest+hypothesis).
"""

from .combine import combine, pick_block, vmem_words  # noqa: F401
from .spmm import spmm  # noqa: F401
from . import ref  # noqa: F401
