"""L1 Pallas kernel: the count-combine contraction.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's CPU hot
loop walks per-vertex neighbor lists under OpenMP; on TPU we restructure it
into a regular bulk operation — the neighbor aggregation becomes a blocked
MXU matmul (`spmm.py`) and this kernel performs the per-vertex color-set
contraction over a *vertex tile* resident in VMEM, with the split tables
(`t0`, `t1`) also VMEM-resident. BlockSpec tiles the vertex dimension; the
set dimension stays whole because the split tables index across it.

Pallas runs under `interpret=True`: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO that both the
pytest suite and the Rust runtime execute. Real-TPU performance is
estimated from the VMEM footprint + MXU utilization in EXPERIMENTS.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget per tile (f32 words) used to choose the vertex-block size.
VMEM_BUDGET_WORDS = 2 * 1024 * 1024  # 8 MiB


def pick_block(c1: int, c2: int, n_sets: int, n_splits: int, max_block: int = 128) -> int:
    """Largest power-of-two vertex tile whose working set fits in VMEM.

    Working set per tile row: passive (c1) + agg (c2) + out (n_sets) +
    the gathered intermediates (2 * n_sets * n_splits during the unrolled
    contraction).
    """
    per_row = c1 + c2 + n_sets + 2 * n_sets * n_splits
    b = max_block
    while b > 1 and b * per_row > VMEM_BUDGET_WORDS:
        b //= 2
    return max(b, 1)


def _combine_kernel(passive_ref, agg_ref, t0_ref, t1_ref, out_ref):
    """out[b,s] = Σ_j passive[b, t0[s,j]] · agg[b, t1[s,j]] for one tile."""
    passive = passive_ref[...]          # [B, C1]
    agg = agg_ref[...]                  # [B, C2]
    t0 = t0_ref[...]                    # [S, J]
    t1 = t1_ref[...]                    # [S, J]
    p = jnp.take(passive, t0, axis=1)   # [B, S, J]
    a = jnp.take(agg, t1, axis=1)       # [B, S, J]
    out_ref[...] = (p * a).sum(axis=-1)


@functools.partial(jax.jit, static_argnames=("block",))
def combine(passive, agg, t0, t1, *, block: int = 0):
    """Pallas count-combine.

    passive [B, C1] f32, agg [B, C2] f32, t0/t1 [S, J] i32 -> [B, S] f32.
    `B` must be a multiple of the tile size (callers pad; the AOT path
    always lowers with B == block).
    """
    b_total, c1 = passive.shape
    _, c2 = agg.shape
    n_sets, n_splits = t0.shape
    if block == 0:
        block = pick_block(c1, c2, n_sets, n_splits)
    block = min(block, b_total)
    assert b_total % block == 0, f"B={b_total} not a multiple of tile {block}"
    grid = (b_total // block,)
    return pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, c1), lambda i: (i, 0)),
            pl.BlockSpec((block, c2), lambda i: (i, 0)),
            pl.BlockSpec((n_sets, n_splits), lambda i: (0, 0)),
            pl.BlockSpec((n_sets, n_splits), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block, n_sets), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b_total, n_sets), jnp.float32),
        interpret=True,
    )(passive, agg, t0, t1)


def vmem_words(c1: int, c2: int, n_sets: int, n_splits: int, block: int) -> int:
    """VMEM footprint estimate (f32 words) of one tile — §Perf reporting."""
    table_words = 2 * n_sets * n_splits  # t0 + t1 (i32 ≈ f32 words)
    row_words = block * (c1 + c2 + n_sets + 2 * n_sets * n_splits)
    return table_words + row_words
