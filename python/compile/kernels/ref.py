"""Pure-jnp oracles for the L1 kernels — the correctness ground truth.

`combine_ref` is the color-coding DP combine (Eq 1, factored form):

    out[b, s] = sum_j passive[b, t0[s, j]] * agg[b, t1[s, j]]

`spmm_ref` is the neighbor aggregation as a dense blocked matmul:

    agg = adj @ active        (adj is a {0,1} adjacency block)

Together they are the exact computation `rust/src/colorcount/engine.rs`
performs natively (aggregate_batch + contract_touched).
"""

import jax.numpy as jnp


def combine_ref(passive, agg, t0, t1):
    """passive [B, C1], agg [B, C2], t0/t1 [S, J] int32 -> out [B, S]."""
    p = jnp.take(passive, t0, axis=1)  # [B, S, J]
    a = jnp.take(agg, t1, axis=1)      # [B, S, J]
    return (p * a).sum(axis=-1)


def spmm_ref(adj, active):
    """adj [B, N] f32 {0,1}, active [N, C2] -> agg [B, C2]."""
    return adj @ active


def fused_ref(adj, active, passive, t0, t1):
    """The L2 composition: SpMM then gathered contraction."""
    return combine_ref(passive, spmm_ref(adj, active), t0, t1)
