"""L1 Pallas kernel: blocked SpMM for the neighbor aggregation.

`agg = adj @ active` where `adj` is a dense {0,1} adjacency block. On a
real TPU this is the MXU-friendly reformulation of the paper's irregular
neighbor-list walk: the HBM→VMEM schedule streams `[BM, BK]` adjacency
tiles against `[BK, C2]` count tiles and accumulates `[BM, C2]` partials in
VMEM — the role the paper's per-thread neighbor chunks played on the Xeon.
The contraction (K) dimension is the grid's minor axis so the accumulator
tile stays resident while K tiles stream (standard Pallas matmul pattern).

interpret=True for CPU-PJRT executability (see combine.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmm_kernel(adj_ref, act_ref, out_ref):
    k_idx = pl.program_id(1)

    @pl.when(k_idx == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += adj_ref[...] @ act_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bk"))
def spmm(adj, active, *, bm: int = 128, bk: int = 128):
    """adj [M, K] f32, active [K, C2] f32 -> [M, C2] f32 (M%bm==K%bk==0)."""
    m, k = adj.shape
    _, c2 = active.shape
    bm = min(bm, m)
    bk = min(bk, k)
    assert m % bm == 0 and k % bk == 0, f"{m}x{k} not tiled by {bm}x{bk}"
    grid = (m // bm, k // bk)
    return pl.pallas_call(
        _spmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk, c2), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, c2), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, c2), jnp.float32),
        interpret=True,
    )(adj, active)
