"""L2: the JAX compute graph the coordinator's combine step lowers from.

The distributed DP's per-(rank, step) update is, in dense-block form,

    out[v, s] += sum_j passive[v, t0[s,j]] * (adj_blk @ active)[v, t1[s,j]]

`combine_block` is the contraction-only entry (the Rust engine aggregates
natively and hands the kernel a ready `agg` block); `fused_block` is the
full SpMM + contraction composition. Both call the L1 Pallas kernels so
the AOT lowering captures them in the same HLO module. Python never runs
on the request path: `aot.py` lowers these once to `artifacts/*.hlo.txt`
and the Rust runtime (`rust/src/runtime/`) loads + executes them via PJRT.
"""

from . import kernels


def combine_block(passive, agg, t0, t1):
    """passive [B,C1], agg [B,C2], t0/t1 [S,J] -> contribution [B,S]."""
    return kernels.combine(passive, agg, t0, t1, block=passive.shape[0])


def fused_block(adj, active, passive, t0, t1):
    """adj [B,N] {0,1}, active [N,C2], passive [B,C1] -> [B,S]."""
    agg = kernels.spmm(adj, active, bm=adj.shape[0], bk=adj.shape[1])
    return kernels.combine(passive, agg, t0, t1, block=passive.shape[0])
