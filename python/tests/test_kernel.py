"""L1 kernel correctness: Pallas vs the pure-jnp oracle (the CORE
correctness signal for the AOT path), swept over shapes/dtypes with
hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import combine, pick_block, ref, spmm

jax.config.update("jax_platform_name", "cpu")


def _mk_tables(rng, c1, c2, s, j):
    t0 = rng.integers(0, c1, size=(s, j), dtype=np.int32)
    t1 = rng.integers(0, c2, size=(s, j), dtype=np.int32)
    return jnp.asarray(t0), jnp.asarray(t1)


@pytest.mark.parametrize("b,c1,c2,s,j", [
    (4, 3, 3, 3, 2),      # u3-1-ish
    (8, 5, 10, 10, 3),    # u5-2-ish
    (16, 7, 21, 35, 4),   # u7-2-ish
    (2, 1, 5, 5, 1),      # degenerate single-split
])
def test_combine_matches_ref(b, c1, c2, s, j):
    rng = np.random.default_rng(b * 1000 + s)
    passive = jnp.asarray(rng.random((b, c1), dtype=np.float32))
    agg = jnp.asarray(rng.random((b, c2), dtype=np.float32))
    t0, t1 = _mk_tables(rng, c1, c2, s, j)
    got = combine(passive, agg, t0, t1, block=b)
    want = ref.combine_ref(passive, agg, t0, t1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_combine_tiles_grid():
    # B larger than the tile: grid must partition correctly
    rng = np.random.default_rng(7)
    b, c1, c2, s, j = 32, 4, 6, 5, 2
    passive = jnp.asarray(rng.random((b, c1), dtype=np.float32))
    agg = jnp.asarray(rng.random((b, c2), dtype=np.float32))
    t0, t1 = _mk_tables(rng, c1, c2, s, j)
    got = combine(passive, agg, t0, t1, block=8)
    want = ref.combine_ref(passive, agg, t0, t1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    c1=st.integers(1, 12),
    c2=st.integers(1, 12),
    s=st.integers(1, 20),
    j=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_combine_hypothesis_sweep(b, c1, c2, s, j, seed):
    rng = np.random.default_rng(seed)
    passive = jnp.asarray(rng.random((b, c1), dtype=np.float32))
    agg = jnp.asarray(rng.random((b, c2), dtype=np.float32))
    t0, t1 = _mk_tables(rng, c1, c2, s, j)
    got = combine(passive, agg, t0, t1, block=b)
    want = ref.combine_ref(passive, agg, t0, t1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([4, 8, 16]),
    k=st.sampled_from([4, 8, 16]),
    c2=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmm_hypothesis_sweep(m, k, c2, seed):
    rng = np.random.default_rng(seed)
    adj = jnp.asarray((rng.random((m, k)) < 0.3).astype(np.float32))
    active = jnp.asarray(rng.random((k, c2), dtype=np.float32))
    got = spmm(adj, active, bm=min(m, 8), bk=min(k, 8))
    np.testing.assert_allclose(got, ref.spmm_ref(adj, active), rtol=1e-5, atol=1e-5)


def test_spmm_k_accumulation():
    # multiple K tiles must accumulate, not overwrite
    rng = np.random.default_rng(3)
    adj = jnp.asarray((rng.random((8, 32)) < 0.5).astype(np.float32))
    active = jnp.asarray(rng.random((32, 5), dtype=np.float32))
    got = spmm(adj, active, bm=8, bk=8)  # 4 K-tiles
    np.testing.assert_allclose(got, ref.spmm_ref(adj, active), rtol=1e-5, atol=1e-5)


def test_pick_block_respects_vmem():
    from compile.kernels.combine import VMEM_BUDGET_WORDS
    b = pick_block(6435, 6435, 6435, 35)
    assert b >= 1
    assert b * (6435 + 6435 + 6435 + 2 * 6435 * 35) <= VMEM_BUDGET_WORDS or b == 1
    assert pick_block(3, 3, 3, 2) == 128  # tiny shapes use the max tile


def test_counts_are_exact_for_integer_inputs():
    # count tables hold small integers; the kernel must be exact on them
    rng = np.random.default_rng(11)
    passive = jnp.asarray(rng.integers(0, 50, (8, 5)).astype(np.float32))
    agg = jnp.asarray(rng.integers(0, 50, (8, 10)).astype(np.float32))
    t0, t1 = _mk_tables(rng, 5, 10, 10, 3)
    got = np.asarray(combine(passive, agg, t0, t1, block=8))
    want = np.asarray(ref.combine_ref(passive, agg, t0, t1))
    assert (got == want).all(), "integer counts must be bit-exact"
