"""The python partition mirror must agree with the Rust side: combine
shapes drive the AOT artifact shapes, so a drift here silently breaks the
XLA engine. The expectations below are locked against the Rust tests."""

from math import comb

from compile.templates import BUILTIN, combine_shapes, partition_template


def test_u3_shapes():
    shapes = {(s.a, s.a1) for s in combine_shapes("u3-1")}
    # P3 rooted at an end splits 3=(1,2)... with dedup the distinct
    # combines are sizes (2: 1+1) and (3: 1+2 or 2+1)
    assert all(a1 + a2 == a for (a, a1), a2 in
               [((a, a1), next(s.a2 for s in combine_shapes("u3-1")
                               if (s.a, s.a1) == (a, a1)))
                for (a, a1) in shapes])
    assert (2, 1) in shapes
    assert any(a == 3 for (a, _) in shapes)


def test_all_builtins_partition():
    for name, (n, edges) in BUILTIN.items():
        dag = partition_template(n, edges)
        assert dag.subs[dag.root].size == n, name
        # children strictly smaller, sizes add up
        for s in dag.subs:
            if not s.is_leaf:
                assert (dag.subs[s.passive].size + dag.subs[s.active].size
                        == s.size), name


def test_shape_combinatorics():
    for name in ["u3-1", "u5-2", "u7-2"]:
        for s in combine_shapes(name):
            assert s.c1 == comb(s.k, s.a1)
            assert s.c2 == comb(s.k, s.a2)
            assert s.n_sets == comb(s.k, s.a)
            assert s.n_splits == comb(s.a, s.a1)


def test_u5_2_known_dag():
    # chair: 5 vertices; the DAG must contain the full-template combine
    shapes = combine_shapes("u5-2")
    assert any(s.a == 5 for s in shapes)
    ks = {s.k for s in shapes}
    assert ks == {5}


def test_dedup_is_effective():
    n, edges = BUILTIN["u7-2"]
    dag = partition_template(n, edges)
    # balanced binary on 7: far fewer distinct shapes than 13 raw splits
    assert len(dag.subs) <= 8
